//! The accelerator top level: Instruction Decoder + Scheduler driving the
//! PM array, loaders, mapper and crossbar (Fig. 3), with the timeline /
//! overlap policy.
//!
//! Timeline model: the stream-based design double-buffers input rows and
//! output stores against compute, so data transfers issued *after* a
//! Schedule can hide inside that Schedule's compute time (`overlap_budget`).
//! Weight loads at a filter-step boundary are not hidden (the PMs are
//! idle waiting for filters — the paper's weight-stationary dataflow
//! reloads filters only once per output-channel tile precisely because
//! this is expensive). The mapper generates cmap/omap concurrently with
//! the CU pass; whichever is slower sets the pass time (§IV-E: maps are
//! generated once per row and broadcast).
//!
//! # Execution engines
//!
//! `Schedule` passes execute on one of two host-side paths selected by
//! [`AccelConfig::exec_engine`]: the fused tile-level GEMM + col2IM
//! engine ([`super::engine`], the default) or the legacy per-tap scalar
//! path (`ProcessingModule::compute_pass_taps`, the differential
//! oracle). Outputs and `CycleReport`s are identical either way — the
//! engine computes the same charges in closed form from the tile's tap
//! census instead of tallying them per tap.
//!
//! The fused engine's GEMM microkernel itself dispatches to an explicit
//! SIMD path where the CPU supports one (`cpu::gemm::GemmKernel` —
//! AVX2 / NEON / NEON+dotprod, force-scalar via the `MM2IM_GEMM_KERNEL`
//! env var), and [`AccelConfig::host_threads`] fans big passes out
//! across a persistent worker pool. Both are pure host-wall-clock
//! levers: every kernel computes bit-identical i32 sums (integer
//! addition reassociates exactly), the parallel split hands each lane
//! disjoint PM accumulators, and the cycle charges are closed-form on
//! the issuing thread — so outputs *and* reports are unchanged, which
//! `rust/tests/gemm_kernels.rs` and `rust/tests/parallel_determinism.rs`
//! lock down.
//!
//! # Zero-copy streams
//!
//! Bulk stream operands are shared, not copied: `LoadInput` rows are
//! [`RowSlice`](super::isa::RowSlice)s aliasing the request tensor's
//! buffer (the Row Buffer stores the same handles), and `LoadWeights`
//! carries `Arc`-backed filter payloads plus a [`WeightSetSig`]
//! precomputed at plan-compile time — the resident-skip check compares
//! signatures instead of re-hashing weight bytes per stream (debug
//! builds re-derive and verify).
//!
//! # Persistence and weight reuse
//!
//! An [`Accelerator`] is a *persistent* instance: [`Accelerator::
//! run_stream`] resets per-layer state (tile registers, maps, row buffer,
//! cycle counters) but the PM filter BRAM survives between streams. The
//! instance remembers the signature of the last filter set it loaded, and
//! a `LoadWeights` whose signature matches the resident set is elided —
//! no DMA, no `axi_weights` cycles, only the instruction decode (the host
//! driver still issues the opcode; the Weight Data Loader acks a resident
//! filter set without a transfer). Elisions are counted in
//! [`CycleReport::weight_loads_skipped`]. This is what makes shard-owned
//! accelerators profitable for same-layer traffic: consecutive streams of
//! the same single-tile layer pay the weight transfer once. Multi-tile
//! layers reload BRAM every stream (only the last set is tracked), but
//! the fused engine's packed-operand LRU still elides the host-side
//! repack for recently seen sets ([`CycleReport::repacks_skipped`] —
//! zero modeled cycles, pure host throughput).
//!
//! # Batched streams
//!
//! [`Accelerator::run_batch`] executes a *batched* stream, in which one
//! `Configure`/`LoadWeights` prologue per tile is followed by per-request
//! row schedules separated by `SelectOutput` markers (see
//! `driver::plan::CompiledPlan::instantiate_batch`). Each `SelectOutput`
//! re-points the output crossbar at that request's output buffer and
//! clears the row buffer so the request's input rows stream fresh.
//! Outputs are byte-identical to running each request's stream alone.
//!
//! ```
//! use mm2im::accel::{Accelerator, AccelConfig};
//! use mm2im::driver::compile_layer;
//! use mm2im::accel::isa::OutMode;
//! use mm2im::tconv::TconvProblem;
//! use mm2im::tensor::Tensor;
//! use mm2im::util::rng::Pcg32;
//!
//! let p = TconvProblem::new(3, 3, 4, 3, 2, 2);
//! let mut rng = Pcg32::new(7);
//! let x = Tensor::<i8>::random(&[p.ih, p.iw, p.ic], &mut rng);
//! let w = Tensor::<i8>::random(&[p.oc, p.ks, p.ks, p.ic], &mut rng);
//! let cfg = AccelConfig::default();
//! let plan = compile_layer(&p, &w, &vec![0; p.oc], None, &cfg, OutMode::Raw32);
//!
//! // Persistent instance: same layer twice — the second stream's weight
//! // load is elided because the filter set is already resident.
//! let mut acc = Accelerator::new(cfg);
//! let first = acc.run_stream(&plan.instantiate(&x)).unwrap();
//! let second = acc.run_stream(&plan.instantiate(&x)).unwrap();
//! assert_eq!(first.raw.data(), second.raw.data());
//! assert_eq!(second.report.weight_loads_skipped, plan.tiles.len() as u64);
//! assert!(second.report.total_cycles < first.report.total_cycles);
//! ```

use super::axi::{instr_cycles, transfer_cycles};
use super::config::{AccelConfig, ExecEngine};
use super::crossbar::Crossbar;
use super::cycles::CycleReport;
use super::engine::Engine;
use super::fault::{ExecError, FaultInjector, FaultKind};
use super::isa::{Instr, OutMode, RowSlice, TileConfig, WeightSet, WeightSetSig};
use super::loaders::RowBuffer;
use super::mapper::Mapper;
use super::pm::{PmCycles, ProcessingModule};
use crate::tconv::problem::TconvProblem;
use crate::tensor::Tensor;

/// Hard cap on batch slots one stream may address — a corrupt stream must
/// not make the simulator allocate unbounded crossbars.
const MAX_BATCH_SLOTS: usize = 65_536;

/// Cycle-level, numerics-exact simulator of one MM2IM instance. See the
/// [module docs](self) for the persistence / weight-reuse contract.
pub struct Accelerator {
    /// Structural + cost configuration of this instance.
    pub cfg: AccelConfig,
    tile: Option<TileConfig>,
    mapper: Option<Mapper>,
    /// Width-tap map cached per tile (invariant across rows; the hardware
    /// mapper regenerates it each row, the simulator caches it — the
    /// per-row mapper *cycles* are still charged).
    cached_taps: Vec<super::mapper::WidthTap>,
    pms: Vec<ProcessingModule>,
    /// Fused GEMM+col2IM engine (used when `cfg.exec_engine` is
    /// [`ExecEngine::Fused`]); its packed filters persist with the
    /// resident set.
    engine: Engine,
    row_buffer: RowBuffer,
    /// Per-batch-slot output assembly; slot 0 is the default target.
    slots: Vec<Option<Crossbar>>,
    cur_slot: usize,
    /// Signature of the filter set currently in PM BRAM. Survives
    /// `reset()` — weight state is exactly what persists across streams.
    resident: Option<WeightSetSig>,
    /// Whether the current tile's `LoadWeights` has executed (transfer
    /// or resident ack) — a `Schedule` before it is a driver bug.
    tile_weights_ready: bool,
    /// Completed-but-unstored rows per PM: (out_row, raw, quant).
    pending_rows: Vec<Option<(usize, Vec<i32>, Vec<i8>)>>,
    /// Recycled (raw, quant) row buffers: `StoreOutput` returns them
    /// here, `Schedule` reuses them — no per-row allocation (§Perf).
    spare_rows: Vec<(Vec<i32>, Vec<i8>)>,
    /// Installed fault injector (serving chaos legs only; `None` in
    /// every non-chaos path, where it costs nothing).
    fault: Option<FaultInjector>,
    report: CycleReport,
    overlap_budget: u64,
}

/// Result of executing an instruction stream for one layer.
#[derive(Debug)]
pub struct ExecResult {
    /// Raw int32 accumulators [Oh, Ow, Oc].
    pub raw: Tensor<i32>,
    /// PPU-requantized int8 outputs [Oh, Ow, Oc] (zeros in Raw32 mode...
    /// identity requant writes saturated values; use `raw` then).
    pub quant: Tensor<i8>,
    /// Cycle accounting for the whole stream.
    pub report: CycleReport,
}

/// Result of executing a batched stream: one output pair per batch slot,
/// a single timeline for the whole batch.
#[derive(Debug)]
pub struct BatchResult {
    /// Per-slot `(raw int32, requantized int8)` outputs, index = slot.
    pub outputs: Vec<(Tensor<i32>, Tensor<i8>)>,
    /// Cycle accounting for the whole batched stream (the amortized
    /// per-request cost is `total_cycles / outputs.len()`).
    pub report: CycleReport,
}

impl Accelerator {
    /// Build a fresh instance: empty PM BRAM, no resident weights.
    pub fn new(cfg: AccelConfig) -> Self {
        let pms = (0..cfg.x_pms).map(|_| ProcessingModule::new()).collect();
        let pending_rows = (0..cfg.x_pms).map(|_| None).collect();
        Self {
            row_buffer: RowBuffer::new(cfg.row_buffer_rows),
            cfg,
            tile: None,
            mapper: None,
            cached_taps: Vec::new(),
            pms,
            engine: Engine::new(),
            slots: vec![None],
            cur_slot: 0,
            resident: None,
            tile_weights_ready: false,
            pending_rows,
            spare_rows: Vec::new(),
            fault: None,
            report: CycleReport::default(),
            overlap_budget: 0,
        }
    }

    /// Execute a full instruction stream (all tiles of one TCONV layer).
    pub fn execute(mut self, stream: &[Instr]) -> Result<ExecResult, ExecError> {
        self.run_stream(stream)
    }

    /// Install a fault injector: every subsequent stream consults it at
    /// the execution boundary (see [`super::fault`]). Serving chaos legs
    /// only — instances without an injector never pay for one.
    pub fn set_fault_injector(&mut self, injector: FaultInjector) {
        self.fault = Some(injector);
    }

    /// Supervision recovery probe. `true` = the instance can execute
    /// streams (always, when no injector is installed); a dead shard's
    /// probe fails until its injector's revive budget is spent.
    pub fn probe(&mut self) -> bool {
        self.fault.as_mut().is_none_or(FaultInjector::on_probe)
    }

    /// Forget the resident filter-set signature, forcing the next
    /// stream's first `LoadWeights` to transfer. The coordinator calls
    /// this when it recovers a poisoned accelerator lock: injected
    /// faults fire only at stream boundaries, so PM state is never
    /// mid-stream after a panic — but dropping the residency shadow is
    /// cheap insurance that a post-panic stream trusts nothing.
    pub fn clear_resident(&mut self) {
        self.resident = None;
    }

    /// Signature of the filter set currently resident in PM BRAM (`None`
    /// on a fresh instance). Read-only: the serving layer's placement
    /// scorer uses it to predict which shard can skip its next
    /// `LoadWeights`, without perturbing the instance.
    pub fn resident_signature(&self) -> Option<WeightSetSig> {
        self.resident
    }

    /// Execute one layer's stream on a *persistent* instance: per-layer
    /// state and cycle counters reset at stream start, so a shard-owned
    /// accelerator can be reused across layers and requests without
    /// reallocation. Weight BRAM state survives between calls — a stream
    /// reloading the resident filter set skips the transfer (see the
    /// [module docs](self)).
    pub fn run_stream(&mut self, stream: &[Instr]) -> Result<ExecResult, ExecError> {
        let mut outputs = self.run_to_outputs(stream)?;
        if outputs.len() != 1 {
            return Err(ExecError::Stream(format!(
                "stream addressed {} output slots; use run_batch for batched streams",
                outputs.len()
            )));
        }
        let (raw, quant) = outputs.pop().expect("one output");
        Ok(ExecResult { raw, quant, report: std::mem::take(&mut self.report) })
    }

    /// Execute a batched stream (one weight prologue per tile, per-request
    /// row schedules spliced behind `SelectOutput` markers). Returns every
    /// slot's outputs plus the single shared timeline.
    pub fn run_batch(&mut self, stream: &[Instr]) -> Result<BatchResult, ExecError> {
        let outputs = self.run_to_outputs(stream)?;
        Ok(BatchResult { outputs, report: std::mem::take(&mut self.report) })
    }

    /// Consult the installed fault injector at a stream boundary —
    /// BEFORE `reset()` and before any instruction executes, so a
    /// faulted stream never leaves the instance mid-layer (retries on
    /// this or another shard start from a consistent state). No-op
    /// without an injector.
    fn check_fault(&mut self, stream: &[Instr]) -> Result<(), ExecError> {
        let Some(inj) = self.fault.as_mut() else { return Ok(()) };
        match inj.on_stream() {
            None => Ok(()),
            Some(FaultKind::Stall(d)) => {
                // A latency spike, not a failure: the stream proceeds
                // normally after the stall, outputs unaffected.
                std::thread::sleep(d);
                Ok(())
            }
            Some(FaultKind::Transient) => Err(ExecError::Transient(format!(
                "injected transient execution fault on shard {} (fault seed {})",
                inj.shard(),
                inj.seed()
            ))),
            Some(FaultKind::CorruptTransfer) => {
                // Model *detection*: a checksum mismatch on the first
                // transfer payload, reported before it is consumed. The
                // Arc-shared payload bytes are never actually mutated.
                let payload = stream
                    .iter()
                    .find_map(|i| match i {
                        Instr::LoadWeights(_) => Some("LoadWeights"),
                        Instr::LoadInput { .. } => Some("LoadInput"),
                        _ => None,
                    })
                    .unwrap_or("transfer");
                Err(ExecError::CorruptTransfer(format!(
                    "checksum mismatch detected on {payload} payload, shard {} (fault seed {})",
                    inj.shard(),
                    inj.seed()
                )))
            }
            Some(FaultKind::Death) => panic!(
                "injected fault: shard {} accelerator died (fault seed {})",
                inj.shard(),
                inj.seed()
            ),
        }
    }

    /// Shared stream loop: reset per-layer state, step every instruction,
    /// then collect and completeness-check every addressed output slot.
    fn run_to_outputs(
        &mut self,
        stream: &[Instr],
    ) -> Result<Vec<(Tensor<i32>, Tensor<i8>)>, ExecError> {
        self.check_fault(stream)?;
        self.reset();
        for instr in stream {
            self.step(instr).map_err(ExecError::Stream)?;
        }
        if self.slots.iter().all(|s| s.is_none()) {
            return Err(ExecError::Stream("stream never configured a tile".into()));
        }
        let slots = std::mem::replace(&mut self.slots, vec![None]);
        let mut outputs = Vec::with_capacity(slots.len());
        for (i, slot) in slots.into_iter().enumerate() {
            let crossbar =
                slot.ok_or_else(|| ExecError::Stream(format!("output slot {i} never populated")))?;
            let p = crossbar_problem(&crossbar);
            if crossbar.rows_stored() != p.oh() * p.oc {
                return Err(ExecError::Stream(format!(
                    "incomplete layer: stored {} rows, expected {} (slot {i})",
                    crossbar.rows_stored(),
                    p.oh() * p.oc
                )));
            }
            outputs.push(crossbar.into_outputs());
        }
        Ok(outputs)
    }

    /// Clear per-layer state (tile registers, maps, row buffer, pending
    /// rows, cycle counters) ahead of a new stream. Deliberately does NOT
    /// clear the PM filter BRAM, its resident-set signature, or the
    /// engine's packed operands — weight persistence across streams is
    /// the point of a shard-owned instance.
    fn reset(&mut self) {
        self.tile = None;
        self.mapper = None;
        self.cached_taps.clear();
        self.engine.reset_tile();
        self.slots = vec![None];
        self.cur_slot = 0;
        self.tile_weights_ready = false;
        for slot in &mut self.pending_rows {
            *slot = None;
        }
        self.row_buffer.clear();
        self.report = CycleReport::default();
        self.overlap_budget = 0;
    }

    /// Decode + execute one instruction (the Instruction Decoder +
    /// Scheduler handshake).
    fn step(&mut self, instr: &Instr) -> Result<(), String> {
        let iw_cycles = instr_cycles(instr.encoded_words(), &self.cfg);
        self.report.instr += iw_cycles;
        self.report.traffic.instr_words += instr.encoded_words();
        self.advance(iw_cycles, false);

        match instr {
            Instr::Configure(tc) => self.configure(tc.clone()),
            Instr::LoadWeights(ws) => self.load_weights(ws),
            Instr::LoadInput { first_row, rows } => self.load_input(*first_row, rows),
            Instr::Schedule { out_row } => self.schedule(*out_row),
            Instr::StoreOutput { out_row } => self.store_output(*out_row),
            Instr::SelectOutput { slot } => self.select_output(*slot),
        }
    }

    fn configure(&mut self, tc: TileConfig) -> Result<(), String> {
        tc.validate(self.cfg.x_pms)?;
        for cb in self.slots.iter().flatten() {
            if crossbar_problem(cb) != tc.problem {
                return Err("problem changed mid-stream; one layer per execute()".into());
            }
        }
        if self.slots[self.cur_slot].is_none() {
            self.slots[self.cur_slot] = Some(Crossbar::new(&tc.problem));
        }
        let mapper = Mapper::configure(&tc.problem);
        // Width taps are row-invariant; generate once per tile.
        self.cached_taps = mapper.row_maps(0, 0, &self.cfg).taps;
        if self.cfg.exec_engine == ExecEngine::Fused {
            self.engine.configure(&tc.problem, tc.oc_count, &self.cached_taps);
        }
        self.mapper = Some(mapper);
        self.row_buffer.clear(); // new filter step re-streams input rows
        self.tile_weights_ready = false;
        self.tile = Some(tc);
        Ok(())
    }

    fn load_weights(&mut self, ws: &WeightSet) -> Result<(), String> {
        let tc = self.tile.as_ref().ok_or("LoadWeights before Configure")?;
        if ws.filters().len() != tc.oc_count {
            return Err(format!(
                "expected {} filters for this tile, got {}",
                tc.oc_count,
                ws.filters().len()
            ));
        }
        let (ks, ic) = (tc.problem.ks, tc.problem.ic);
        // The signature was computed once at plan-compile time (the
        // `WeightSet` constructor is the only way to produce one, so it
        // cannot go stale); the old hot path re-hashed every weight
        // byte here on every stream. Debug builds re-derive and verify
        // anyway.
        debug_assert_eq!(
            ws.sig(),
            WeightSetSig::of(ws.filters(), ks, ic),
            "stream carries a stale weight-set signature"
        );
        self.tile_weights_ready = true;
        if self.resident == Some(ws.sig()) {
            // The identical filter set is already in PM BRAM (persistent
            // instance, weight-stationary reuse): ack without a DMA. The
            // instruction words were already charged by `step`.
            self.report.weight_loads_skipped += 1;
            return Ok(());
        }
        for (pm, payload) in self.pms.iter_mut().zip(ws.filters()) {
            pm.load_filter(payload, ks, ic);
        }
        if self.cfg.exec_engine == ExecEngine::Fused
            && self.engine.load_filters(ws.filters(), ks, ic, ws.sig())
        {
            // The BRAM transfer happened (resident miss), but the engine
            // still held this set's packed GEMM operands — host-side
            // repack elided (multi-tile layers hit this every stream).
            self.report.repacks_skipped += 1;
        }
        let bytes = ws.transfer_bytes();
        let cycles = transfer_cycles(bytes, &self.cfg);
        self.report.axi_weights += cycles;
        self.report.traffic.weight_bytes += bytes;
        self.report.weight_loads += 1;
        self.resident = Some(ws.sig());
        // Weight loads stall the array (filter-step boundary): never hidden.
        self.advance(cycles, false);
        Ok(())
    }

    /// `SelectOutput { slot }`: re-point the output DMA at another batch
    /// slot's output buffer and start that request's input stream fresh.
    fn select_output(&mut self, slot: usize) -> Result<(), String> {
        let tc = self.tile.as_ref().ok_or("SelectOutput before Configure")?;
        if slot >= MAX_BATCH_SLOTS {
            return Err(format!("batch slot {slot} exceeds cap {MAX_BATCH_SLOTS}"));
        }
        if slot >= self.slots.len() {
            self.slots.resize_with(slot + 1, || None);
        }
        if self.slots[slot].is_none() {
            self.slots[slot] = Some(Crossbar::new(&tc.problem));
        }
        self.cur_slot = slot;
        // The new request's rows must stream fresh; resident rows belong
        // to the previous slot's input tensor.
        self.row_buffer.clear();
        Ok(())
    }

    fn load_input(&mut self, first_row: usize, rows: &[RowSlice]) -> Result<(), String> {
        let tc = self.tile.as_ref().ok_or("LoadInput before Configure")?;
        let row_bytes = tc.problem.iw * tc.problem.ic;
        let mut bytes = 0u64;
        for (i, row) in rows.iter().enumerate() {
            if row.len() != row_bytes {
                return Err(format!(
                    "input row {} has {} bytes, expected {row_bytes}",
                    first_row + i,
                    row.len()
                ));
            }
            // Zero-copy: the Row Buffer shares the stream's row handle
            // (an Arc bump), it does not copy the bytes into BRAM.
            self.row_buffer.push(first_row + i, row.clone());
            bytes += row.len() as u64;
        }
        let cycles = transfer_cycles(bytes, &self.cfg);
        self.report.axi_inputs += cycles;
        self.report.traffic.input_bytes += bytes;
        self.advance(cycles, self.cfg.overlap_axi_compute);
        Ok(())
    }

    fn schedule(&mut self, out_row: usize) -> Result<(), String> {
        let tc = self.tile.clone().ok_or("Schedule before Configure")?;
        let mapper = self.mapper.as_ref().ok_or("no mapper")?;
        if !self.tile_weights_ready {
            return Err("Schedule before LoadWeights (driver bug)".into());
        }
        let p = tc.problem;
        if out_row >= p.oh() {
            return Err(format!("Schedule row {out_row} out of range (Oh={})", p.oh()));
        }

        for pm in self.pms.iter_mut().take(tc.oc_count) {
            pm.begin_row(p.ow());
        }

        let mut row_time = 0u64;
        let mut lockstep = PmCycles::default();
        // Kind-dependent mapper walk: Overlapped visits Iw*Ks candidates
        // per pass, Segregated only the survivors (+ stride^2 sub-kernel
        // setup). The tap census is row-invariant, so both are too.
        let surviving = self.cached_taps.len();
        let mapper_cycles_per_pass =
            p.mapper.mapper_walk_slots(p.iw, p.ks, p.stride, surviving)
                * self.cfg.mapper_cycles_per_tap;
        let candidate_taps = p.mapper.candidate_taps(p.iw, p.ks, surviving);
        for (ihr, kh) in mapper.contributing_rows(out_row) {
            // Disjoint field borrows: broadcast the Row Buffer line and the
            // cached tap map to the PM array without copying (§Perf).
            let row_buffer = &self.row_buffer;
            let taps = &self.cached_taps;
            let input_row = row_buffer
                .get(ihr)
                .ok_or_else(|| format!("input row {ihr} not resident (driver bug)"))?;

            let pass = match self.cfg.exec_engine {
                ExecEngine::Fused => self.engine.compute_pass(
                    input_row,
                    kh,
                    &mut self.pms[..tc.oc_count],
                    &self.cfg,
                ),
                ExecEngine::Scalar => {
                    let mut pass = PmCycles::default();
                    for pm in self.pms.iter_mut().take(tc.oc_count) {
                        // Lockstep array: identical charges per PM; keep
                        // one copy.
                        pass = pm.compute_pass_taps(input_row, taps, kh, candidate_taps, &self.cfg);
                    }
                    pass
                }
            };
            lockstep.add(&pass);

            let cu_time = pass.cu_load + pass.cu_compute;
            let pass_time = if self.cfg.mapper_enabled {
                self.report.mapper += mapper_cycles_per_pass;
                cu_time.max(mapper_cycles_per_pass)
            } else {
                // Ablation: maps come over AXI instead (per §III-C up to
                // 35% of T_total): 4 B per surviving tap, one DMA
                // descriptor per row pass (the pre-Mapper design fetched
                // each row's map from main memory before computing it).
                let omap_bytes = taps.len() as u64 * 4;
                let omap_cycles = transfer_cycles(omap_bytes, &self.cfg);
                self.report.axi_omap += omap_cycles;
                self.report.traffic.omap_bytes += omap_bytes;
                cu_time + omap_cycles
            };
            row_time += pass_time;
        }

        // Row completion: PPU requant + drain per PM (lockstep), into
        // recycled row buffers (no allocation on the steady-state path).
        let mut ppu_cycles = 0u64;
        for (i, pm) in self.pms.iter_mut().take(tc.oc_count).enumerate() {
            let (mut raw, mut quant) = self.spare_rows.pop().unwrap_or_default();
            ppu_cycles = pm.finish_row_into(&self.cfg, &mut raw, &mut quant);
            if self.pending_rows[i].is_some() {
                return Err(format!("PM {i} row overwritten before StoreOutput"));
            }
            self.pending_rows[i] = Some((out_row, raw, quant));
        }
        lockstep.ppu += ppu_cycles;
        row_time += ppu_cycles;

        self.report.pm.add(&lockstep);
        for pm in self.pms.iter_mut().take(tc.oc_count) {
            self.report.effectual_macs += std::mem::take(&mut pm.effectual_macs);
            self.report.wasted_macs += std::mem::take(&mut pm.skipped_macs);
        }

        // Compute advances the timeline and replenishes the overlap budget
        // for the next row's input/output transfers.
        self.report.total_cycles += row_time;
        self.overlap_budget = row_time;
        Ok(())
    }

    fn store_output(&mut self, out_row: usize) -> Result<(), String> {
        let tc = self.tile.clone().ok_or("StoreOutput before Configure")?;
        let cb = self.slots[self.cur_slot].as_mut().ok_or("no crossbar")?;
        let int8 = tc.out_mode == OutMode::Int8;
        let mut stored = 0usize;
        for (i, slot) in self.pending_rows.iter_mut().take(tc.oc_count).enumerate() {
            let (row, raw, quant) = slot.take().ok_or_else(|| {
                format!("StoreOutput({out_row}): PM {i} has no completed row")
            })?;
            if row != out_row {
                return Err(format!("StoreOutput({out_row}) but PM {i} holds row {row}"));
            }
            cb.store_row(row, tc.oc_base + i, &raw, &quant);
            stored += 1;
            // Hand the drained buffers back for the next Schedule.
            self.spare_rows.push((raw, quant));
        }
        let bytes = (stored * tc.problem.ow() * if int8 { 1 } else { 4 }) as u64;
        let cycles = transfer_cycles(bytes, &self.cfg);
        self.report.axi_outputs += cycles;
        self.report.traffic.output_bytes += bytes;
        self.advance(cycles, self.cfg.overlap_axi_compute);
        Ok(())
    }

    /// Advance the timeline by `cycles`, optionally hiding inside the
    /// pending compute overlap budget.
    fn advance(&mut self, cycles: u64, overlappable: bool) {
        if overlappable {
            let hidden = cycles.min(self.overlap_budget);
            self.overlap_budget -= hidden;
            self.report.total_cycles += cycles - hidden;
        } else {
            self.report.total_cycles += cycles;
        }
    }
}

fn crossbar_problem(cb: &Crossbar) -> TconvProblem {
    cb.problem()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::driver::instructions::build_layer_stream;
    use crate::tconv::reference;
    use crate::util::rng::Pcg32;

    fn run_case(p: TconvProblem, seed: u64, cfg: AccelConfig) {
        let mut rng = Pcg32::new(seed);
        let x = Tensor::<i8>::random(&[p.ih, p.iw, p.ic], &mut rng);
        let w = Tensor::<i8>::random(&[p.oc, p.ks, p.ks, p.ic], &mut rng);
        let bias: Vec<i32> = (0..p.oc).map(|i| (i as i32 % 7) * 5 - 10).collect();
        let stream = build_layer_stream(&p, &x, &w, &bias, None, &cfg, OutMode::Raw32);
        let result = Accelerator::new(cfg).execute(&stream).expect("execute");
        let want = reference::direct_i32(&p, &x, &w, Some(&bias));
        assert_eq!(result.raw.data(), want.data(), "{p}");
        assert!(result.report.total_cycles > 0);
    }

    #[test]
    fn bit_exact_across_problem_shapes() {
        let cfg = AccelConfig::default;
        run_case(TconvProblem::new(2, 2, 2, 3, 2, 1), 1, cfg());
        run_case(TconvProblem::new(7, 7, 32, 5, 16, 2), 2, cfg());
        run_case(TconvProblem::new(5, 3, 8, 3, 4, 2), 3, cfg());
        run_case(TconvProblem::new(4, 4, 4, 2, 4, 2), 4, cfg());
        run_case(TconvProblem::new(3, 3, 4, 2, 4, 3), 5, cfg()); // Ks < S
        run_case(TconvProblem::new(1, 1, 21, 4, 21, 4), 6, cfg()); // FCN
        run_case(TconvProblem::new(4, 4, 48, 5, 11, 2), 7, cfg()); // Oc not /X
    }

    #[test]
    fn bit_exact_on_scalar_engine_too() {
        let mut cfg = AccelConfig::default();
        cfg.exec_engine = ExecEngine::Scalar;
        run_case(TconvProblem::new(7, 7, 32, 5, 16, 2), 2, cfg.clone());
        run_case(TconvProblem::new(4, 4, 48, 5, 11, 2), 7, cfg);
    }

    #[test]
    fn bit_exact_with_small_pm_array_and_uf() {
        let mut cfg = AccelConfig::default();
        cfg.x_pms = 2;
        cfg.uf = 4;
        run_case(TconvProblem::new(5, 5, 13, 5, 7, 2), 8, cfg);
    }

    #[test]
    fn ablations_preserve_numerics() {
        let mut no_mapper = AccelConfig::default();
        no_mapper.mapper_enabled = false;
        run_case(TconvProblem::new(6, 6, 16, 5, 8, 2), 9, no_mapper);
        let mut no_skip = AccelConfig::default();
        no_skip.cmap_skip_enabled = false;
        run_case(TconvProblem::new(6, 6, 16, 5, 8, 2), 10, no_skip);
    }

    /// The Segregated walk is numerics-neutral end to end and, on a
    /// heavily cropped layer, strictly cheaper: the mapper stops walking
    /// ineffectual candidates, and under the cmap-skip ablation there is
    /// no wasted work left to restore.
    #[test]
    fn segregated_mapper_bit_exact_and_cheaper_under_cropping() {
        use crate::tconv::problem::MapperKind;
        let p = TconvProblem::new(6, 6, 16, 5, 8, 2); // Ks > S: real cropping
        let seg = p.with_mapper(MapperKind::Segregated);
        run_case(seg, 13, AccelConfig::default());
        let mut scalar = AccelConfig::default();
        scalar.exec_engine = ExecEngine::Scalar;
        run_case(seg, 13, scalar);

        let mut rng = Pcg32::new(14);
        let x = Tensor::<i8>::random(&[p.ih, p.iw, p.ic], &mut rng);
        let w = Tensor::<i8>::random(&[p.oc, p.ks, p.ks, p.ic], &mut rng);
        let bias = vec![0i32; p.oc];
        let run = |p: &TconvProblem, cfg: AccelConfig| {
            let stream = build_layer_stream(p, &x, &w, &bias, None, &cfg, OutMode::Raw32);
            Accelerator::new(cfg).execute(&stream).unwrap()
        };

        let over = run(&p, AccelConfig::default());
        let segr = run(&seg, AccelConfig::default());
        assert_eq!(over.raw.data(), segr.raw.data(), "mapper kind must not change numerics");
        assert!(segr.report.mapper < over.report.mapper, "segregated walk visits fewer slots");

        // cmap-skip ablation: Overlapped recomputes the cropped taps,
        // Segregated never had them as candidates.
        let mut no_skip = AccelConfig::default();
        no_skip.cmap_skip_enabled = false;
        let over_ns = run(&p, no_skip.clone());
        let segr_ns = run(&seg, no_skip);
        assert_eq!(over_ns.raw.data(), segr_ns.raw.data());
        assert!(over_ns.report.wasted_macs > 0, "overlapped ablation restores waste");
        assert_eq!(segr_ns.report.wasted_macs, 0, "no ineffectual candidates at rest");
        assert!(segr_ns.report.total_cycles < over_ns.report.total_cycles);
    }

    #[test]
    fn mapper_ablation_costs_more_cycles() {
        let p = TconvProblem::new(7, 7, 32, 5, 16, 2);
        let mut rng = Pcg32::new(11);
        let x = Tensor::<i8>::random(&[p.ih, p.iw, p.ic], &mut rng);
        let w = Tensor::<i8>::random(&[p.oc, p.ks, p.ks, p.ic], &mut rng);
        let bias = vec![0i32; p.oc];

        let cfg = AccelConfig::default();
        let stream = build_layer_stream(&p, &x, &w, &bias, None, &cfg, OutMode::Raw32);
        let with = Accelerator::new(cfg.clone()).execute(&stream).unwrap();

        let mut cfg2 = AccelConfig::default();
        cfg2.mapper_enabled = false;
        let stream2 = build_layer_stream(&p, &x, &w, &bias, None, &cfg2, OutMode::Raw32);
        let without = Accelerator::new(cfg2).execute(&stream2).unwrap();

        assert!(without.report.total_cycles > with.report.total_cycles);
        assert!(without.report.traffic.omap_bytes > 0);
        assert_eq!(with.report.traffic.omap_bytes, 0);
    }

    #[test]
    fn utilization_increases_with_ic() {
        let cfg = AccelConfig::default();
        let mut utils = Vec::new();
        for ic in [16usize, 64, 256] {
            let p = TconvProblem::new(7, 7, ic, 5, 16, 2);
            let mut rng = Pcg32::new(12);
            let x = Tensor::<i8>::random(&[p.ih, p.iw, p.ic], &mut rng);
            let w = Tensor::<i8>::random(&[p.oc, p.ks, p.ks, p.ic], &mut rng);
            let stream =
                build_layer_stream(&p, &x, &w, &vec![0; p.oc], None, &cfg, OutMode::Raw32);
            let r = Accelerator::new(cfg.clone()).execute(&stream).unwrap();
            utils.push(r.report.utilization(&cfg));
        }
        assert!(utils[0] < utils[1] && utils[1] < utils[2], "{utils:?}");
    }

    #[test]
    fn persistent_instance_reusable_across_layers() {
        let cfg = AccelConfig::default();
        let p1 = TconvProblem::new(3, 3, 4, 3, 2, 1);
        let p2 = TconvProblem::new(4, 4, 8, 5, 6, 2);
        let mut acc = Accelerator::new(cfg.clone());
        for (p, seed) in [(p1, 21u64), (p2, 22), (p1, 23)] {
            let mut rng = Pcg32::new(seed);
            let x = Tensor::<i8>::random(&[p.ih, p.iw, p.ic], &mut rng);
            let w = Tensor::<i8>::random(&[p.oc, p.ks, p.ks, p.ic], &mut rng);
            let bias = vec![0i32; p.oc];
            let stream = build_layer_stream(&p, &x, &w, &bias, None, &cfg, OutMode::Raw32);
            let got = acc.run_stream(&stream).expect("reused instance");
            let want = reference::direct_i32(&p, &x, &w, Some(&bias));
            assert_eq!(got.raw.data(), want.data(), "{p} seed {seed}");
            // Cycle accounting must match a fresh instance (no leakage).
            let fresh = Accelerator::new(cfg.clone()).execute(&stream).unwrap();
            assert_eq!(got.report.total_cycles, fresh.report.total_cycles);
        }
    }

    #[test]
    fn resident_weights_skip_fires_and_preserves_numerics() {
        let cfg = AccelConfig::default();
        let p = TconvProblem::new(4, 4, 8, 3, 6, 2); // Oc=6 <= X=8: one tile
        let mut rng = Pcg32::new(31);
        let x = Tensor::<i8>::random(&[p.ih, p.iw, p.ic], &mut rng);
        let w = Tensor::<i8>::random(&[p.oc, p.ks, p.ks, p.ic], &mut rng);
        let bias = vec![0i32; p.oc];
        let stream = build_layer_stream(&p, &x, &w, &bias, None, &cfg, OutMode::Raw32);

        let mut acc = Accelerator::new(cfg);
        let first = acc.run_stream(&stream).unwrap();
        let second = acc.run_stream(&stream).unwrap();
        assert_eq!(first.raw.data(), second.raw.data(), "skip must not change numerics");
        assert_eq!((first.report.weight_loads, first.report.weight_loads_skipped), (1, 0));
        assert_eq!((second.report.weight_loads, second.report.weight_loads_skipped), (0, 1));
        assert_eq!(second.report.traffic.weight_bytes, 0, "no filter bytes moved");
        assert!(
            second.report.total_cycles < first.report.total_cycles,
            "resident skip must drop cycles: {} vs {}",
            second.report.total_cycles,
            first.report.total_cycles
        );
    }

    #[test]
    fn different_weights_never_skip() {
        let cfg = AccelConfig::default();
        let p = TconvProblem::new(4, 4, 8, 3, 6, 2);
        let mut acc = Accelerator::new(cfg.clone());
        for seed in [41u64, 42] {
            let mut rng = Pcg32::new(seed);
            let x = Tensor::<i8>::random(&[p.ih, p.iw, p.ic], &mut rng);
            let w = Tensor::<i8>::random(&[p.oc, p.ks, p.ks, p.ic], &mut rng);
            let stream =
                build_layer_stream(&p, &x, &w, &vec![0; p.oc], None, &cfg, OutMode::Raw32);
            let got = acc.run_stream(&stream).unwrap();
            assert_eq!((got.report.weight_loads, got.report.weight_loads_skipped), (1, 0));
            let want = reference::direct_i32(&p, &x, &w, Some(&vec![0; p.oc]));
            assert_eq!(got.raw.data(), want.data());
        }
    }

    /// Multi-tile layers reload BRAM every stream (the resident-skip
    /// tracks only the last set), but the engine's packed-operand LRU
    /// elides the host-side repack from the second stream on — with
    /// numerics and modeled cycles identical to the first stream.
    #[test]
    fn multi_tile_streams_skip_repacks_not_cycles() {
        let cfg = AccelConfig::default();
        let p = TconvProblem::new(5, 5, 8, 3, 12, 2); // Oc=12 over X=8: two tiles
        let mut rng = Pcg32::new(61);
        let x = Tensor::<i8>::random(&[p.ih, p.iw, p.ic], &mut rng);
        let w = Tensor::<i8>::random(&[p.oc, p.ks, p.ks, p.ic], &mut rng);
        let bias = vec![0i32; p.oc];
        let stream = build_layer_stream(&p, &x, &w, &bias, None, &cfg, OutMode::Raw32);
        let want = reference::direct_i32(&p, &x, &w, Some(&bias));

        let mut acc = Accelerator::new(cfg);
        let first = acc.run_stream(&stream).unwrap();
        assert_eq!(first.report.weight_loads, 2);
        assert_eq!(first.report.repacks_skipped, 0, "cold engine packs both tiles");
        let second = acc.run_stream(&stream).unwrap();
        // Tile 1's load misses BRAM (tile 2's set is resident), tile 2's
        // load misses too (tile 1's set just displaced it) — both
        // transfer again, but neither repacks.
        assert_eq!(second.report.weight_loads, 2);
        assert_eq!(second.report.weight_loads_skipped, 0);
        assert_eq!(second.report.repacks_skipped, 2, "both tiles hit the packed LRU");
        assert_eq!(second.raw.data(), want.data());
        assert_eq!(
            first.report, second.report,
            "repack elision must not change any modeled charge"
        );
    }

    #[test]
    fn batched_stream_outputs_match_per_request() {
        use crate::driver::instructions::compile_layer;
        let cfg = AccelConfig::default();
        let p = TconvProblem::new(5, 5, 8, 3, 12, 2); // Oc=12 over X=8: two tiles
        let mut rng = Pcg32::new(51);
        let w = Tensor::<i8>::random(&[p.oc, p.ks, p.ks, p.ic], &mut rng);
        let bias: Vec<i32> = (0..p.oc).map(|i| i as i32 - 2).collect();
        let xs: Vec<Tensor<i8>> = (0..3)
            .map(|_| Tensor::<i8>::random(&[p.ih, p.iw, p.ic], &mut rng))
            .collect();
        let refs: Vec<&Tensor<i8>> = xs.iter().collect();

        let plan = compile_layer(&p, &w, &bias, None, &cfg, OutMode::Raw32);
        let stream = plan.instantiate_batch(&refs);
        // Acceptance criterion: one LoadWeights per tile, not per request.
        let loads = stream.iter().filter(|i| matches!(i, Instr::LoadWeights(_))).count();
        assert_eq!(loads, plan.tiles.len());

        let batch = Accelerator::new(cfg.clone()).run_batch(&stream).unwrap();
        assert_eq!(batch.outputs.len(), 3);
        let mut singles_cycles = 0u64;
        for (k, x) in xs.iter().enumerate() {
            let single = Accelerator::new(cfg.clone()).execute(&plan.instantiate(x)).unwrap();
            assert_eq!(batch.outputs[k].0.data(), single.raw.data(), "slot {k}");
            singles_cycles += single.report.total_cycles;
        }
        assert_eq!(batch.report.weight_loads, plan.tiles.len() as u64);
        assert!(
            batch.report.total_cycles < singles_cycles,
            "batch must amortize: {} vs {}",
            batch.report.total_cycles,
            singles_cycles
        );
    }

    #[test]
    fn incomplete_stream_rejected() {
        let p = TconvProblem::new(3, 3, 4, 3, 2, 1);
        let tc = TileConfig { problem: p, oc_base: 0, oc_count: 2, out_mode: OutMode::Raw32 };
        let err = Accelerator::new(AccelConfig::default())
            .execute(&[Instr::Configure(tc)])
            .unwrap_err();
        assert!(err.to_string().contains("incomplete"), "{err}");
    }

    #[test]
    fn schedule_without_weights_is_driver_bug() {
        let p = TconvProblem::new(3, 3, 4, 3, 2, 1);
        let tc = TileConfig { problem: p, oc_base: 0, oc_count: 2, out_mode: OutMode::Raw32 };
        let mut acc = Accelerator::new(AccelConfig::default());
        acc.reset();
        acc.step(&Instr::Configure(tc)).unwrap();
        let err = acc.step(&Instr::Schedule { out_row: 0 }).unwrap_err();
        assert!(err.contains("before LoadWeights"), "{err}");
    }

    #[test]
    fn schedule_without_input_rows_is_driver_bug() {
        let p = TconvProblem::new(3, 3, 4, 3, 2, 1);
        let tc = TileConfig { problem: p, oc_base: 0, oc_count: 2, out_mode: OutMode::Raw32 };
        let fp = super::super::isa::FilterPayload {
            weights: vec![0i8; p.ks * p.ks * p.ic].into(),
            bias: 0,
            qmult_m: 1 << 30,
            qmult_shift: 1,
            zp_out: 0,
        };
        let stream = vec![
            Instr::Configure(tc),
            Instr::LoadWeights(WeightSet::new(vec![fp.clone(), fp], p.ks, p.ic)),
            Instr::Schedule { out_row: 0 },
        ];
        let mut acc = Accelerator::new(AccelConfig::default());
        let mut failed = false;
        for i in &stream {
            if let Err(e) = acc.step(i) {
                assert!(e.contains("not resident"), "{e}");
                failed = true;
                break;
            }
        }
        assert!(failed);
    }
}
