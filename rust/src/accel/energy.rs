//! Energy model for the PYNQ-Z1 deployment.
//!
//! Power is decomposed as FPGA static + utilization-scaled dynamic power
//! for the accelerator, and per-core active power for the Cortex-A9 (see
//! `cpu::cost_model::cpu_power_w`). Constants are anchored to the paper's
//! operating points: Table II reports ~15 GOPs/W at ~12 GOPs on the
//! DCGAN layers, implying ≈0.8 W attributed to the accelerator; PYNQ-Z1
//! Zynq-7020 static power is ≈0.25 W. Reported energy numbers reproduce
//! the paper's *ratios* (Table IV: 1.6–1.8x reduction), not absolute
//! joules (DESIGN.md §8).

use super::config::AccelConfig;
use super::cycles::CycleReport;

/// FPGA static + board overhead attributed to the accelerator, W.
/// (Zynq-7020 PL static ≈0.25 W plus the DDR/PS share of accelerator
/// traffic.)
pub const FPGA_STATIC_W: f64 = 0.45;
/// Dynamic power of the design at 100% MAC-array utilization, W
/// (PL switching + DDR traffic). Anchored so that the DCGAN_2 operating
/// point (~12.35 GOPs at ~19% utilization) gives the paper's ~15 GOPs/W.
pub const FPGA_DYNAMIC_FULL_W: f64 = 2.00;
/// Host-side A9 core shepherding the delegate while the FPGA runs, W.
pub const DRIVER_CORE_W: f64 = 0.45;

/// Average accelerator power for a run with the given utilization.
pub fn accel_power_w(utilization: f64) -> f64 {
    FPGA_STATIC_W + FPGA_DYNAMIC_FULL_W * utilization.clamp(0.0, 1.0)
}

/// Energy (J) for one accelerated layer execution.
pub fn accel_energy_j(report: &CycleReport, cfg: &AccelConfig) -> f64 {
    let t = report.seconds(cfg);
    (accel_power_w(report.utilization(cfg)) + DRIVER_CORE_W) * t
}

/// GOPs/W as Table II reports it: achieved GOPs over accelerator power.
pub fn gops_per_watt(report: &CycleReport, algorithm_macs: u64, cfg: &AccelConfig) -> f64 {
    report.achieved_gops(algorithm_macs, cfg) / accel_power_w(report.utilization(cfg))
}

/// Energy (J) for a CPU-only execution of `seconds` on `threads` cores.
pub fn cpu_energy_j(seconds: f64, threads: usize) -> f64 {
    crate::cpu::cost_model::cpu_power_w(threads) * seconds
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn power_monotone_in_utilization() {
        assert!(accel_power_w(0.0) < accel_power_w(0.5));
        assert!(accel_power_w(0.5) < accel_power_w(1.0));
        assert_eq!(accel_power_w(2.0), accel_power_w(1.0)); // clamped
        assert!((accel_power_w(0.5) - (FPGA_STATIC_W + 0.5 * FPGA_DYNAMIC_FULL_W)).abs() < 1e-12);
    }

    #[test]
    fn table2_gops_per_watt_ballpark() {
        // At ~24% utilization and ~12 GOPs the paper reports ~15 GOPs/W.
        let cfg = AccelConfig::default();
        let mut r = CycleReport::default();
        // 12.35 GOPs over 33.97 ms: macs = gops*t/2
        r.total_cycles = (0.03397 * cfg.freq_hz) as u64;
        let macs = (12.35e9 * 0.03397 / 2.0) as u64;
        r.effectual_macs = (macs as f64 * 0.8) as u64; // ~20% cropped
        let gpw = gops_per_watt(&r, macs, &cfg);
        assert!(gpw > 8.0 && gpw < 25.0, "GOPs/W = {gpw}");
    }

    #[test]
    fn cpu_energy_scales_with_threads_and_time() {
        assert!(cpu_energy_j(1.0, 2) > cpu_energy_j(1.0, 1));
        assert!((cpu_energy_j(2.0, 1) - 2.0 * cpu_energy_j(1.0, 1)).abs() < 1e-12);
    }
}
