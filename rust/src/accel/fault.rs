//! Deterministic, seeded fault injection for the serving fleet.
//!
//! Production serving needs a fault story the test net can *replay*: a
//! transient execution error, a corrupted DMA transfer, a shard that
//! stalls, a shard that dies — each must be reproducible bit-for-bit
//! from a seed printed in the failing assert, exactly like the
//! differential sweep's per-case RNG seeds. This module provides that
//! plumbing:
//!
//! * [`ExecError`] — the typed error the accelerator boundary
//!   ([`Accelerator::run_stream`](super::Accelerator::run_stream) /
//!   `run_batch` / `execute`) returns instead of a bare `String`, so
//!   the executor, delegate, and coordinator can classify failures
//!   (retryable vs driver bug) without string matching.
//! * [`FaultSpec`] — a seeded fault scenario, buildable in code or
//!   parsed from the `MM2IM_FAULT_SPEC` env var
//!   (`"seed=7,transient=0.1,kill=1@3,revive=2"`), with a round-trip
//!   [`std::fmt::Display`] so assert messages can print the exact
//!   reproducing spec.
//! * [`FaultPlan`] — an installed spec: hands each shard a
//!   [`FaultInjector`] and each worker its abort point.
//! * [`FaultInjector`] — the per-shard decision stream. Seeded as
//!   `Pcg32::with_stream(seed, shard + 1)`, so a fault decision depends
//!   only on `(seed, shard, per-shard stream ordinal)` — never on
//!   thread interleaving across shards — and a chaos run is replayable
//!   no matter how the OS schedules workers.
//!
//! # Injection point and structural safety
//!
//! Faults are checked at **stream execution boundaries** — the top of
//! the simulator's stream loop, before `reset()` and before any
//! instruction executes. A faulted stream therefore never leaves the
//! accelerator mid-layer: internal state is whatever the last
//! *completed* stream left, which is exactly the state a retry on
//! another shard (or the same shard, post-recovery) can tolerate. The
//! corrupted-transfer fault models *detection* (a checksum mismatch on
//! a `LoadWeights`/`LoadInput` payload, reported before the payload is
//! consumed); stream payloads are `Arc`-shared with compiled plans and
//! are never actually mutated.

use crate::telemetry::{Counter, Tree};
use crate::util::rng::Pcg32;
use std::fmt;
use std::time::Duration;

/// Typed error from accelerator stream execution. Replaces the former
/// `Result<_, String>` at the `run_stream`/`run_batch`/`execute`
/// boundary so callers can classify failures without string matching.
///
/// All variants are retry-safe from the coordinator's point of view: a
/// failed stream produced no outputs (see the structural-safety note in
/// the [module docs](self)), so re-executing its requests can never
/// double-serve them.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ExecError {
    /// A transient execution failure (injected, or a would-be-recoverable
    /// hardware event). Retrying the same stream may succeed.
    Transient(String),
    /// A transfer checksum mismatch was detected on a `LoadWeights` or
    /// `LoadInput` payload before it was consumed. Retrying re-issues
    /// the transfer.
    CorruptTransfer(String),
    /// A malformed instruction stream — a driver bug (e.g. `Schedule`
    /// before `LoadWeights`, incomplete layer). Deterministic for a
    /// given stream, but harmless to retry.
    Stream(String),
}

impl fmt::Display for ExecError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Self::Transient(m) => write!(f, "transient execution fault: {m}"),
            Self::CorruptTransfer(m) => write!(f, "corrupt transfer detected: {m}"),
            Self::Stream(m) => write!(f, "stream error: {m}"),
        }
    }
}

impl std::error::Error for ExecError {}

/// The fault classes an injector can fire at a stream boundary.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// Fail this stream with [`ExecError::Transient`]; the next stream
    /// draws fresh.
    Transient,
    /// Fail this stream with [`ExecError::CorruptTransfer`].
    CorruptTransfer,
    /// Stall (sleep) for the spec's `stall_ms` before executing the
    /// stream normally — a latency spike, not a failure.
    Stall(Duration),
    /// The shard dies: this and every subsequent stream panics until a
    /// recovery probe succeeds (see [`FaultInjector::on_probe`]).
    Death,
}

/// A seeded fault scenario. Build with [`FaultSpec::new`] + the chained
/// setters, or parse the `MM2IM_FAULT_SPEC` grammar:
///
/// ```text
/// seed=7,transient=0.1,corrupt=0.05,stall=0.1,stall_ms=2,kill=1@3,revive=2,abort=0@4
/// ```
///
/// * `seed=N` — base RNG seed (per-shard streams derive from it).
/// * `transient=P` / `corrupt=P` / `stall=P` — per-stream probabilities
///   (cumulative; their sum must stay ≤ 1).
/// * `stall_ms=N` — stall duration in milliseconds (default 1).
/// * `kill=S@K` — shard `S` dies at its `K`-th stream (0-indexed).
/// * `revive=N` — a dead shard's recovery probe succeeds after `N`
///   failed probes (absent = never recovers).
/// * `abort=W@K` — worker thread `W` panics at its `K`-th batch take
///   (0-indexed), exercising the coordinator's join-capture path.
///
/// ```
/// use mm2im::accel::FaultSpec;
/// let spec = FaultSpec::parse("seed=7,transient=0.25,kill=1@3,revive=2").unwrap();
/// assert_eq!(spec, FaultSpec::parse(&spec.to_string()).unwrap());
/// ```
#[derive(Clone, Debug, PartialEq)]
pub struct FaultSpec {
    /// Base seed; every per-shard injector derives its own independent
    /// PCG stream from it.
    pub seed: u64,
    /// Per-stream probability of a transient execution failure.
    pub transient: f64,
    /// Per-stream probability of a detected corrupt transfer.
    pub corrupt: f64,
    /// Per-stream probability of a latency stall.
    pub stall: f64,
    /// Stall duration in milliseconds.
    pub stall_ms: u64,
    /// `(shard, stream ordinal)` at which that shard dies.
    pub kill: Option<(usize, u64)>,
    /// Failed probes before a dead shard recovers (`None` = never).
    pub revive_after: Option<u32>,
    /// `(worker index, take ordinal)` at which that worker panics.
    pub abort: Option<(usize, u64)>,
}

impl FaultSpec {
    /// A spec with the given seed and no faults enabled; chain setters
    /// to arm fault classes.
    pub fn new(seed: u64) -> Self {
        Self {
            seed,
            transient: 0.0,
            corrupt: 0.0,
            stall: 0.0,
            stall_ms: 1,
            kill: None,
            revive_after: None,
            abort: None,
        }
    }

    /// Arm per-stream transient failures with probability `p`.
    pub fn transient(mut self, p: f64) -> Self {
        self.transient = p;
        self
    }

    /// Arm per-stream corrupt-transfer detection with probability `p`.
    pub fn corrupt(mut self, p: f64) -> Self {
        self.corrupt = p;
        self
    }

    /// Arm per-stream stalls with probability `p`, each `ms` long.
    pub fn stall(mut self, p: f64, ms: u64) -> Self {
        self.stall = p;
        self.stall_ms = ms;
        self
    }

    /// Kill shard `shard` at its `at`-th stream (0-indexed).
    pub fn kill(mut self, shard: usize, at: u64) -> Self {
        self.kill = Some((shard, at));
        self
    }

    /// Let a dead shard's probe succeed after `n` failed probes.
    pub fn revive_after(mut self, n: u32) -> Self {
        self.revive_after = Some(n);
        self
    }

    /// Panic worker `worker` at its `at`-th batch take (0-indexed).
    pub fn abort(mut self, worker: usize, at: u64) -> Self {
        self.abort = Some((worker, at));
        self
    }

    /// Parse the `MM2IM_FAULT_SPEC` grammar (see the type docs).
    pub fn parse(s: &str) -> Result<Self, String> {
        let mut spec = Self::new(0);
        let mut saw_seed = false;
        for field in s.split(',').map(str::trim).filter(|f| !f.is_empty()) {
            let (key, value) = field
                .split_once('=')
                .ok_or_else(|| format!("fault spec field '{field}' is not key=value"))?;
            let prob = |v: &str| -> Result<f64, String> {
                let p: f64 =
                    v.parse().map_err(|_| format!("fault spec {key}={v}: not a number"))?;
                if !(0.0..=1.0).contains(&p) {
                    return Err(format!("fault spec {key}={v}: probability outside [0, 1]"));
                }
                Ok(p)
            };
            let at = |v: &str| -> Result<(usize, u64), String> {
                let (idx, ord) = v
                    .split_once('@')
                    .ok_or_else(|| format!("fault spec {key}={v}: expected INDEX@ORDINAL"))?;
                Ok((
                    idx.parse().map_err(|_| format!("fault spec {key}={v}: bad index"))?,
                    ord.parse().map_err(|_| format!("fault spec {key}={v}: bad ordinal"))?,
                ))
            };
            match key {
                "seed" => {
                    spec.seed =
                        value.parse().map_err(|_| format!("fault spec seed={value}: bad u64"))?;
                    saw_seed = true;
                }
                "transient" => spec.transient = prob(value)?,
                "corrupt" => spec.corrupt = prob(value)?,
                "stall" => spec.stall = prob(value)?,
                "stall_ms" => {
                    spec.stall_ms = value
                        .parse()
                        .map_err(|_| format!("fault spec stall_ms={value}: bad u64"))?;
                }
                "kill" => spec.kill = Some(at(value)?),
                "revive" => {
                    spec.revive_after = Some(
                        value
                            .parse()
                            .map_err(|_| format!("fault spec revive={value}: bad u32"))?,
                    );
                }
                "abort" => spec.abort = Some(at(value)?),
                other => return Err(format!("fault spec: unknown key '{other}'")),
            }
        }
        if !saw_seed {
            return Err("fault spec: missing required 'seed=N' field".into());
        }
        if spec.transient + spec.corrupt + spec.stall > 1.0 {
            return Err("fault spec: transient + corrupt + stall probabilities exceed 1".into());
        }
        Ok(spec)
    }

    /// Read `MM2IM_FAULT_SPEC` from the environment. `Ok(None)` when the
    /// variable is unset or empty; `Err` when it is set but malformed.
    pub fn from_env() -> Result<Option<Self>, String> {
        match std::env::var("MM2IM_FAULT_SPEC") {
            Ok(s) if s.trim().is_empty() => Ok(None),
            Ok(s) => Self::parse(&s).map(Some),
            Err(_) => Ok(None),
        }
    }
}

impl fmt::Display for FaultSpec {
    /// Round-trip printable: `FaultSpec::parse(&spec.to_string())`
    /// reproduces the spec, so assert messages carry a replayable
    /// scenario.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "seed={}", self.seed)?;
        if self.transient > 0.0 {
            write!(f, ",transient={}", self.transient)?;
        }
        if self.corrupt > 0.0 {
            write!(f, ",corrupt={}", self.corrupt)?;
        }
        if self.stall > 0.0 {
            write!(f, ",stall={},stall_ms={}", self.stall, self.stall_ms)?;
        }
        if let Some((s, k)) = self.kill {
            write!(f, ",kill={s}@{k}")?;
        }
        if let Some(n) = self.revive_after {
            write!(f, ",revive={n}")?;
        }
        if let Some((w, k)) = self.abort {
            write!(f, ",abort={w}@{k}")?;
        }
        Ok(())
    }
}

/// An installed [`FaultSpec`]: the coordinator builds one per server and
/// derives per-shard injectors and per-worker abort points from it.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultPlan {
    spec: FaultSpec,
}

impl FaultPlan {
    /// Install a spec as a plan.
    pub fn new(spec: FaultSpec) -> Self {
        Self { spec }
    }

    /// Plan from `MM2IM_FAULT_SPEC` (`Ok(None)` when unset).
    pub fn from_env() -> Result<Option<Self>, String> {
        Ok(FaultSpec::from_env()?.map(Self::new))
    }

    /// The underlying spec (printable, replayable).
    pub fn spec(&self) -> &FaultSpec {
        &self.spec
    }

    /// The injector for `shard`'s accelerator. Deterministic in
    /// `(spec.seed, shard)` alone.
    pub fn injector_for_shard(&self, shard: usize) -> FaultInjector {
        FaultInjector {
            shard,
            seed: self.spec.seed,
            // Stream `shard + 1` keeps shard 0 off the default stream,
            // so shard injectors never alias workload RNGs seeded with
            // `Pcg32::new(spec.seed)`.
            rng: Pcg32::with_stream(self.spec.seed, shard as u64 + 1),
            transient: self.spec.transient,
            corrupt: self.spec.corrupt,
            stall: self.spec.stall,
            stall_ms: self.spec.stall_ms,
            kill_at: match self.spec.kill {
                Some((s, at)) if s == shard => Some(at),
                _ => None,
            },
            revive_after: self.spec.revive_after,
            streams: 0,
            dead: false,
            probes_failed: 0,
            counters: None,
        }
    }

    /// The batch-take ordinal at which `worker` should panic, if any.
    pub fn abort_for_worker(&self, worker: usize) -> Option<u64> {
        match self.spec.abort {
            Some((w, at)) if w == worker => Some(at),
            _ => None,
        }
    }
}

/// Fleet-wide injected-fault counters, shared by every attached
/// injector (the tree re-opens the same `faults/injected/*` paths).
#[derive(Clone, Debug)]
struct FaultCounters {
    transient: Counter,
    corrupt_transfer: Counter,
    stall: Counter,
    death: Counter,
}

/// Per-shard fault decision stream, installed into that shard's
/// [`Accelerator`](super::Accelerator). One decision per executed
/// stream, drawn from a PCG stream private to `(seed, shard)` — see the
/// [module docs](self) for the determinism argument.
#[derive(Clone, Debug)]
pub struct FaultInjector {
    shard: usize,
    seed: u64,
    rng: Pcg32,
    transient: f64,
    corrupt: f64,
    stall: f64,
    stall_ms: u64,
    kill_at: Option<u64>,
    revive_after: Option<u32>,
    /// Ordinal of the next stream this shard executes.
    streams: u64,
    dead: bool,
    probes_failed: u32,
    counters: Option<FaultCounters>,
}

impl FaultInjector {
    /// The shard this injector belongs to.
    pub fn shard(&self) -> usize {
        self.shard
    }

    /// The spec seed — printed in every injected failure so chaos runs
    /// are replayable from the message alone.
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Whether the shard is currently dead (a fired `kill` with no
    /// successful revive probe yet).
    pub fn is_dead(&self) -> bool {
        self.dead
    }

    /// Count every subsequent fired fault under `tree`'s
    /// `faults/injected/{transient,corrupt_transfer,stall,death}`
    /// counters. Injectors of one fleet share the paths, so the
    /// counters aggregate across shards; `death` counts *decisions*
    /// (one per stream attempted against a dead shard), not kills.
    /// Purely observational — the decision stream is untouched, so a
    /// chaos run stays bit-for-bit replayable with or without a tree.
    pub fn attach_telemetry(&mut self, tree: &Tree) {
        let node = tree.node("faults");
        let node = node.child("injected");
        self.counters = Some(FaultCounters {
            transient: node.counter("transient"),
            corrupt_transfer: node.counter("corrupt_transfer"),
            stall: node.counter("stall"),
            death: node.counter("death"),
        });
    }

    /// Decide this stream's fate. Called once at the top of every stream
    /// execution; consumes exactly one decision draw per stream, so the
    /// outcome sequence depends only on `(seed, shard, ordinal)`.
    pub fn on_stream(&mut self) -> Option<FaultKind> {
        let ordinal = self.streams;
        self.streams += 1;
        if self.kill_at == Some(ordinal) {
            self.dead = true;
        }
        let fault = if self.dead {
            Some(FaultKind::Death)
        } else {
            let r = self.rng.f32() as f64;
            if r < self.transient {
                Some(FaultKind::Transient)
            } else if r < self.transient + self.corrupt {
                Some(FaultKind::CorruptTransfer)
            } else if r < self.transient + self.corrupt + self.stall {
                Some(FaultKind::Stall(Duration::from_millis(self.stall_ms)))
            } else {
                None
            }
        };
        if let (Some(c), Some(kind)) = (&self.counters, fault) {
            match kind {
                FaultKind::Transient => c.transient.inc(),
                FaultKind::CorruptTransfer => c.corrupt_transfer.inc(),
                FaultKind::Stall(_) => c.stall.inc(),
                FaultKind::Death => c.death.inc(),
            }
        }
        fault
    }

    /// A supervision recovery probe. Healthy (or merely flaky) shards
    /// always pass; a dead shard fails until `revive_after` probes have
    /// failed, then recovers (and subsequent streams execute normally —
    /// its `kill` ordinal is spent).
    pub fn on_probe(&mut self) -> bool {
        if !self.dead {
            return true;
        }
        self.probes_failed += 1;
        match self.revive_after {
            Some(n) if self.probes_failed > n => {
                self.dead = false;
                self.probes_failed = 0;
                true
            }
            _ => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_parse_round_trips_and_validates() {
        let spec = FaultSpec::parse(
            "seed=7,transient=0.25,corrupt=0.1,stall=0.05,stall_ms=3,kill=1@3,revive=2,abort=0@4",
        )
        .unwrap();
        assert_eq!(spec.seed, 7);
        assert_eq!(spec.kill, Some((1, 3)));
        assert_eq!(spec.revive_after, Some(2));
        assert_eq!(spec.abort, Some((0, 4)));
        assert_eq!(FaultSpec::parse(&spec.to_string()).unwrap(), spec);

        // Builder and grammar agree.
        let built = FaultSpec::new(7)
            .transient(0.25)
            .corrupt(0.1)
            .stall(0.05, 3)
            .kill(1, 3)
            .revive_after(2)
            .abort(0, 4);
        assert_eq!(built, spec);

        assert!(FaultSpec::parse("transient=0.5").unwrap_err().contains("seed"));
        assert!(FaultSpec::parse("seed=1,transient=1.5").unwrap_err().contains("[0, 1]"));
        assert!(FaultSpec::parse("seed=1,bogus=3").unwrap_err().contains("unknown key"));
        assert!(FaultSpec::parse("seed=1,kill=3").unwrap_err().contains("INDEX@ORDINAL"));
        assert!(FaultSpec::parse("seed=1,transient=0.6,corrupt=0.6").unwrap_err().contains("exceed"));
    }

    #[test]
    fn injector_streams_are_deterministic_and_shard_independent() {
        let plan = FaultPlan::new(FaultSpec::new(42).transient(0.3).corrupt(0.2));
        let draw = |shard: usize, n: usize| -> Vec<Option<FaultKind>> {
            let mut inj = plan.injector_for_shard(shard);
            (0..n).map(|_| inj.on_stream()).collect()
        };
        // Same (seed, shard) => same decision sequence, every time.
        assert_eq!(draw(0, 64), draw(0, 64));
        assert_eq!(draw(1, 64), draw(1, 64));
        // Distinct shards draw independent sequences.
        assert_ne!(draw(0, 64), draw(1, 64));
        // Roughly the armed rates (seeded, so exact counts are stable).
        let faults = draw(0, 256).iter().filter(|f| f.is_some()).count();
        assert!((64..192).contains(&faults), "half-armed injector fired {faults}/256");
    }

    #[test]
    fn kill_is_permanent_until_revive_probes_succeed() {
        let plan = FaultPlan::new(FaultSpec::new(9).kill(1, 2).revive_after(2));
        let mut inj = plan.injector_for_shard(1);
        assert_eq!(inj.on_stream(), None);
        assert_eq!(inj.on_stream(), None);
        assert_eq!(inj.on_stream(), Some(FaultKind::Death), "dies at ordinal 2");
        assert_eq!(inj.on_stream(), Some(FaultKind::Death), "death is sticky");
        assert!(inj.is_dead());
        assert!(!inj.on_probe(), "probe 1 fails");
        assert!(!inj.on_probe(), "probe 2 fails");
        assert!(inj.on_probe(), "probe 3 recovers the shard");
        assert!(!inj.is_dead());
        assert_eq!(inj.on_stream(), None, "revived shard executes normally");

        // The other shard never dies.
        let mut other = plan.injector_for_shard(0);
        assert!((0..16).all(|_| other.on_stream().is_none()));
        // Without revive, death is forever.
        let mut forever =
            FaultPlan::new(FaultSpec::new(9).kill(0, 0)).injector_for_shard(0);
        assert_eq!(forever.on_stream(), Some(FaultKind::Death));
        assert!((0..8).all(|_| !forever.on_probe()));
    }

    #[test]
    fn abort_targets_one_worker() {
        let plan = FaultPlan::new(FaultSpec::new(3).abort(2, 5));
        assert_eq!(plan.abort_for_worker(2), Some(5));
        assert_eq!(plan.abort_for_worker(0), None);
        assert_eq!(plan.abort_for_worker(3), None);
    }

    #[test]
    fn env_spec_absent_is_none() {
        // The suite never sets MM2IM_FAULT_SPEC globally; chaos legs set
        // it per-process. Absent or empty must read as "no faults".
        if std::env::var("MM2IM_FAULT_SPEC").is_err() {
            assert_eq!(FaultPlan::from_env().unwrap(), None);
        }
    }
}
