//! The MM2IM accelerator — a cycle-level, numerics-exact simulator of the
//! microarchitecture in Fig. 3/4 of the paper.
//!
//! Component map (paper → module):
//! * Instruction Decoder + micro-ISA (Table I)  → [`isa`], [`sim`]
//! * Scheduler                                  → [`sim`] (drives the step loop)
//! * Weight Data Loader / Dynamic Input Loader / Row Buffer → [`loaders`]
//! * MM2IM Mapper (Algorithm 2 in hardware)     → [`mapper`]
//! * Processing Module array (CU + AU + PPU)    → [`pm`]
//! * fused GEMM+col2IM execution engine (host fast path) → [`engine`]
//! * Output Crossbar                            → [`crossbar`]
//! * AXI-Stream + DMA                           → [`axi`]
//! * cycle accounting / energy / FPGA resources → [`cycles`], [`energy`], [`resources`]
//! * deterministic fault injection (serving chaos) → [`fault`]
//!
//! The simulator computes **real int8 numerics** (bit-exact against
//! `tconv::reference`) while accounting cycles per component with the
//! calibrated cost constants in [`config`] (calibration story:
//! EXPERIMENTS.md §Calibration).

pub mod axi;
pub mod config;
pub mod crossbar;
pub mod cycles;
pub mod energy;
pub mod engine;
pub mod fault;
pub mod isa;
pub mod loaders;
pub mod mapper;
pub mod pm;
pub mod resources;
pub mod sim;

pub use config::{AccelConfig, ExecEngine};
pub use cycles::CycleReport;
pub use fault::{ExecError, FaultInjector, FaultKind, FaultPlan, FaultSpec};
pub use isa::{Instr, Opcode, OutMode, RowSlice, TileConfig, WeightSet, WeightSetSig};
pub use sim::{Accelerator, BatchResult, ExecResult};
