//! Accelerator configuration + calibrated cost constants.
//!
//! The structural parameters (X, UF, frequency) are the paper's own
//! instantiation (§IV: X=8, UF=16, 200 MHz on a PYNQ-Z1). The per-op cost
//! constants model the HLS pipeline behaviour; they were calibrated so the
//! simulator's end-to-end latencies land in the band of the paper's
//! Table II measurements for the DCGAN-class layers (see EXPERIMENTS.md
//! §Calibration for the fit and the known deviations on the
//! large-feature-map StyleTransfer layers).

/// Which host-side compute path executes `Schedule` passes. Both paths
/// are bit-identical and charge identical cycles (the engine computes
/// its charges in closed form from the tap census instead of tallying
/// them scalar-by-scalar); they differ only in host wall-clock. The
/// differential net in `rust/tests/engine_differential.rs` locks the
/// equivalence down across the sweep sample and both ablations.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecEngine {
    /// Fused tile-level GEMM + col2IM scatter (`accel::engine`, the
    /// default): each pass runs as blocked int8→int32 GEMMs over packed
    /// per-(kh, kw) filter operands, scattered into the PM accumulators
    /// through the cached omap.
    Fused,
    /// Legacy per-tap scalar dot products in each PM
    /// (`ProcessingModule::compute_pass_taps`) — the differential oracle.
    Scalar,
}

/// Structural + cost configuration of one MM2IM instance.
#[derive(Clone, Debug)]
pub struct AccelConfig {
    /// Number of Processing Modules (the paper's X); `filter_step` in
    /// Algorithm 1 equals this.
    pub x_pms: usize,
    /// Unrolling factor: MACs per cycle per Compute Unit (tiles I_c).
    pub uf: usize,
    /// Fabric clock (PYNQ-Z1 design runs at 200 MHz).
    pub freq_hz: f64,
    /// AXI-Stream payload bytes per fabric cycle (32-bit stream).
    pub axi_bytes_per_cycle: usize,
    /// DMA descriptor setup cost per transfer (driver + DataMover).
    pub dma_setup_cycles: u64,
    /// Cycles to decode one instruction word.
    pub instr_decode_cycles: u64,
    /// Initiation interval of the CU dot-product pipeline per UF-beat.
    pub cu_initiation_interval: u64,
    /// Pipeline fill/drain latency per dot product (accumulator tree +
    /// cmap check + out-muxer handshake). This is what makes small-I_c
    /// layers inefficient on the accelerator (and is why the paper's
    /// speedup *grows* with I_c — §V-B takeaway ii: the dot product
    /// amortizes the fixed pipeline cost when I_c is large).
    pub cu_pipeline_latency: u64,
    /// If true (matches the paper's PE array), the input pixel is
    /// re-streamed into the PE registers for every weight column; if
    /// false the CU caches the pixel across the row's taps.
    pub cu_reload_input_per_tap: bool,
    /// CU->AU FIFO drain latency at the end of each output row.
    pub fifo_drain_cycles: u64,
    /// PPU cycles per output element (requantize + activation + stream).
    pub ppu_cycles_per_output: u64,
    /// Mapper cycles per visited tap (Algorithm 2 walks Ks*Ks per row).
    pub mapper_cycles_per_tap: u64,
    /// MM2IM Mapper present (paper's design). When false — the §III-C
    /// ablation — omap/cmap are *transferred* over AXI instead of
    /// generated on-chip, reproducing the "up to 35% of latency" insight.
    pub mapper_enabled: bool,
    /// Compute-map skipping of cropped partials. When false — ablation —
    /// the CUs compute every partial like the baseline IOM method and
    /// the AU discards the cropped ones.
    pub cmap_skip_enabled: bool,
    /// Overlap input-row streaming / output store with compute (the
    /// stream-based design double-buffers the Row Buffer).
    pub overlap_axi_compute: bool,
    /// Input row buffer capacity in rows (BRAM budget; Dynamic Input
    /// Loader evicts oldest).
    pub row_buffer_rows: usize,
    /// Host-side compute path for `Schedule` passes (see [`ExecEngine`]).
    /// Purely a host-performance choice: streams, outputs and the cycle
    /// model are identical either way, so it is deliberately **not**
    /// part of [`AccelConfig::fingerprint`] — compiled plans are shared
    /// across engines.
    pub exec_engine: ExecEngine,
    /// Host execution lanes for the fused engine's per-pass GEMM +
    /// col2IM work: 1 (the default) runs serial, N > 1 fans each big
    /// enough pass (see [`AccelConfig::host_parallel_min_macs`]) out
    /// across N lanes (the issuing thread plus N-1 persistent pooled
    /// workers), 0 auto-detects the machine's available parallelism.
    /// Like `exec_engine` this is purely host wall-clock: outputs and
    /// `CycleReport` are bit-identical for every value (locked down by
    /// `rust/tests/parallel_determinism.rs`), so it too is excluded
    /// from [`AccelConfig::fingerprint`].
    pub host_threads: usize,
    /// Minimum per-pass MAC volume (`taps * Oc_tile * Ic`) before a
    /// pass fans out to the worker pool; smaller passes run serial
    /// because dispatch costs more than the compute. Host-only, not
    /// fingerprinted. Set to 0 to force the parallel path (tests).
    pub host_parallel_min_macs: u64,
}

impl Default for AccelConfig {
    fn default() -> Self {
        Self {
            x_pms: 8,
            uf: 16,
            freq_hz: 200.0e6,
            axi_bytes_per_cycle: 4,
            dma_setup_cycles: 64,
            instr_decode_cycles: 4,
            cu_initiation_interval: 1,
            cu_pipeline_latency: 10,
            cu_reload_input_per_tap: true,
            fifo_drain_cycles: 8,
            ppu_cycles_per_output: 2,
            mapper_cycles_per_tap: 1,
            mapper_enabled: true,
            cmap_skip_enabled: true,
            overlap_axi_compute: true,
            row_buffer_rows: 16,
            exec_engine: ExecEngine::Fused,
            host_threads: 1,
            host_parallel_min_macs: 1 << 17,
        }
    }
}

impl AccelConfig {
    /// Peak MAC throughput (MACs/cycle) of the PM array.
    pub fn peak_macs_per_cycle(&self) -> u64 {
        (self.x_pms * self.uf) as u64
    }

    /// Peak arithmetic throughput in GOPs (1 MAC = 2 ops).
    pub fn peak_gops(&self) -> f64 {
        2.0 * self.peak_macs_per_cycle() as f64 * self.freq_hz / 1e9
    }

    /// Dot-product cycles for a depth-`ic` column: ceil(ic/UF) beats at
    /// the CU initiation interval.
    pub fn dot_cycles(&self, ic: usize) -> u64 {
        let beats = ic.div_ceil(self.uf) as u64;
        beats * self.cu_initiation_interval
    }

    /// Wall-clock seconds of `cycles` at the configured fabric clock.
    pub fn seconds(&self, cycles: u64) -> f64 {
        cycles as f64 / self.freq_hz
    }

    /// [`AccelConfig::host_threads`] with the 0 = auto case resolved to
    /// the machine's available parallelism.
    pub fn resolved_host_threads(&self) -> usize {
        match self.host_threads {
            0 => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
            n => n,
        }
    }

    /// Order-stable FNV-1a fingerprint over every field the stream or
    /// its cycle accounting sees, for compiled-plan cache keying
    /// (`driver::plan::PlanKey`): two configs differing in any such
    /// field must not share cached plans. Floats hash by bit pattern.
    /// [`AccelConfig::exec_engine`], [`AccelConfig::host_threads`] and
    /// [`AccelConfig::host_parallel_min_macs`] are excluded on purpose —
    /// they change neither streams nor cycles, so every host execution
    /// mode shares one plan.
    pub fn fingerprint(&self) -> u64 {
        let words = [
            self.x_pms as u64,
            self.uf as u64,
            self.freq_hz.to_bits(),
            self.axi_bytes_per_cycle as u64,
            self.dma_setup_cycles,
            self.instr_decode_cycles,
            self.cu_initiation_interval,
            self.cu_pipeline_latency,
            self.cu_reload_input_per_tap as u64,
            self.fifo_drain_cycles,
            self.ppu_cycles_per_output,
            self.mapper_cycles_per_tap,
            self.mapper_enabled as u64,
            self.cmap_skip_enabled as u64,
            self.overlap_axi_compute as u64,
            self.row_buffer_rows as u64,
        ];
        let mut h = crate::util::hash::Fnv::new();
        for w in words {
            h.word(w);
        }
        h.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_instantiation_peaks() {
        let c = AccelConfig::default();
        assert_eq!(c.peak_macs_per_cycle(), 128);
        assert!((c.peak_gops() - 51.2).abs() < 1e-9);
    }

    #[test]
    fn dot_cycles_tiles_ic_by_uf() {
        let c = AccelConfig::default();
        assert_eq!(c.dot_cycles(16), 1); // 1 beat at II=1
        assert_eq!(c.dot_cycles(17), 2); // 2 beats
        assert_eq!(c.dot_cycles(1024), 64);
        assert_eq!(c.dot_cycles(1), 1);
    }

    #[test]
    fn fingerprint_distinguishes_configs() {
        let a = AccelConfig::default();
        assert_eq!(a.fingerprint(), AccelConfig::default().fingerprint());
        let mut b = AccelConfig::default();
        b.uf = 8;
        assert_ne!(a.fingerprint(), b.fingerprint());
        let mut c = AccelConfig::default();
        c.mapper_enabled = false;
        assert_ne!(a.fingerprint(), c.fingerprint());
    }

    #[test]
    fn fingerprint_ignores_exec_engine() {
        let fused = AccelConfig::default();
        let scalar = AccelConfig { exec_engine: ExecEngine::Scalar, ..AccelConfig::default() };
        assert_eq!(fused.fingerprint(), scalar.fingerprint(), "plans are shared across engines");
    }

    #[test]
    fn fingerprint_ignores_host_parallelism_knobs() {
        let serial = AccelConfig::default();
        let wide = AccelConfig {
            host_threads: 8,
            host_parallel_min_macs: 0,
            ..AccelConfig::default()
        };
        assert_eq!(
            serial.fingerprint(),
            wide.fingerprint(),
            "plans are shared across host thread counts"
        );
    }

    #[test]
    fn host_threads_auto_resolves_to_at_least_one() {
        let auto = AccelConfig { host_threads: 0, ..AccelConfig::default() };
        assert!(auto.resolved_host_threads() >= 1);
        let four = AccelConfig { host_threads: 4, ..AccelConfig::default() };
        assert_eq!(four.resolved_host_threads(), 4);
        assert_eq!(AccelConfig::default().resolved_host_threads(), 1, "serial by default");
    }

    #[test]
    fn seconds_at_200mhz() {
        let c = AccelConfig::default();
        assert!((c.seconds(200_000_000) - 1.0).abs() < 1e-12);
    }
}
