//! The micro-ISA (Table I of the paper) and its binary encoding.
//!
//! | Opcode | Description                                          |
//! |--------|------------------------------------------------------|
//! | 0x01   | Configure TCONV (sets configuration registers)       |
//! | 0x02   | Loads Bias and Filter (activates Weight Data Loader) |
//! | 0x04   | Load Input (activates Dynamic Input Loader)          |
//! | 0x08   | Schedule TCONV (activates Scheduler)                 |
//! | 0x10   | Store Output (activates Output Crossbar)             |
//! | 0x20   | Select Output slot (driver extension, layer batching) |
//!
//! Instructions are produced by the host driver (`driver::instructions`)
//! and consumed by the simulator's decoder. The typed [`Instr`] carries
//! the operand payload; `encoded_words()` gives the AXI footprint of the
//! same instruction in the wire format (1 opcode word + operand words),
//! which is what the cycle model charges.
//!
//! # Zero-copy operands
//!
//! Bulk operands are *shared*, never copied into the stream: input rows
//! ride as [`RowSlice`]s (`Arc` views into the request tensor's buffer)
//! and filter bytes as `Arc<[i8]>` inside [`FilterPayload`]. A
//! [`WeightSet`] additionally carries the [`WeightSetSig`] computed once
//! at plan-compile time, so the accelerator's resident-skip check
//! compares two 128-bit signatures instead of re-hashing every weight
//! byte per stream.
//!
//! Opcode 0x20 is not in the paper's Table I: it is the serving layer's
//! extension for weight-reuse batching. It re-points the output DMA base
//! address at another request's output buffer, so one
//! `Configure`/`LoadWeights` prologue per tile can serve a whole batch of
//! inputs (see `driver::plan::CompiledPlan::instantiate_batch`).

use crate::tconv::problem::TconvProblem;
use crate::util::hash::Fnv;
use std::sync::Arc;

/// Wire-format opcodes (Table I values, plus the 0x20 batching extension).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum Opcode {
    /// 0x01 — set configuration registers for one output-channel tile.
    Configure = 0x01,
    /// 0x02 — load bias + filters (activates the Weight Data Loader).
    LoadWeights = 0x02,
    /// 0x04 — stream input rows (activates the Dynamic Input Loader).
    LoadInput = 0x04,
    /// 0x08 — compute one output row (activates the Scheduler).
    Schedule = 0x08,
    /// 0x10 — drain one output row (activates the Output Crossbar).
    StoreOutput = 0x10,
    /// 0x20 — select the output slot subsequent stores target (driver
    /// extension for weight-reuse layer batching).
    SelectOutput = 0x20,
}

impl Opcode {
    /// Decode a wire byte, `None` for invalid encodings.
    pub fn from_byte(b: u8) -> Option<Self> {
        match b {
            0x01 => Some(Self::Configure),
            0x02 => Some(Self::LoadWeights),
            0x04 => Some(Self::LoadInput),
            0x08 => Some(Self::Schedule),
            0x10 => Some(Self::StoreOutput),
            0x20 => Some(Self::SelectOutput),
            _ => None,
        }
    }
}

/// What the PPU emits: raw int32 accumulators (testing / f32 pipelines
/// quantize later) or requantized int8 (the TFLite integration).
/// `Hash` because the mode is part of the compiled-plan cache key.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum OutMode {
    /// Raw int32 accumulators.
    Raw32,
    /// PPU-requantized int8.
    Int8,
}

/// Operands of opcode 0x01 — one `filter_step` tile of a TCONV layer.
/// `PartialEq` because the multi-variant batch splicer
/// ([`crate::driver::plan::CompiledPlan::instantiate_batch_multi`])
/// asserts that chain-mate plans agree on every tile's configuration
/// before sharing one `Configure` between their weight sets.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TileConfig {
    /// Geometry of the *whole* layer (oc = total output channels).
    pub problem: TconvProblem,
    /// First output channel of this tile.
    pub oc_base: usize,
    /// Channels in this tile (<= X; the PMs each take one filter).
    pub oc_count: usize,
    /// Output numeric mode of the PPU.
    pub out_mode: OutMode,
}

impl TileConfig {
    /// Check the tile against the PM-array width and layer geometry.
    pub fn validate(&self, x_pms: usize) -> Result<(), String> {
        if self.oc_count == 0 || self.oc_count > x_pms {
            return Err(format!("oc_count {} exceeds PM array {x_pms}", self.oc_count));
        }
        if self.oc_base + self.oc_count > self.problem.oc {
            return Err(format!(
                "tile [{}, {}) out of range for Oc={}",
                self.oc_base,
                self.oc_base + self.oc_count,
                self.problem.oc
            ));
        }
        Ok(())
    }
}

/// A shared, zero-copy view of one input row: an `Arc`-backed byte
/// buffer (typically a whole request tensor's buffer) plus the row's
/// span. Cloning bumps the `Arc` — the instruction stream and the Row
/// Buffer hand the same bytes around without copying them. (§Perf: the
/// driver used to copy every input row into the stream and the Dynamic
/// Input Loader copied it again into BRAM.)
#[derive(Clone, Debug)]
pub struct RowSlice {
    buf: Arc<Vec<i8>>,
    start: usize,
    len: usize,
}

impl RowSlice {
    /// View of `buf[start .. start + len]`.
    pub fn new(buf: Arc<Vec<i8>>, start: usize, len: usize) -> Self {
        assert!(start + len <= buf.len(), "row slice out of bounds");
        Self { buf, start, len }
    }

    /// The row's bytes.
    pub fn as_slice(&self) -> &[i8] {
        &self.buf[self.start..self.start + self.len]
    }

    /// Bytes in the row.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when the row holds no bytes.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// True when this row aliases `buf` (zero-copy regression hook: a
    /// spliced stream's rows must point into the request tensor's own
    /// buffer, proving no byte was copied).
    pub fn shares_buffer(&self, buf: &Arc<Vec<i8>>) -> bool {
        Arc::ptr_eq(&self.buf, buf)
    }
}

impl From<Vec<i8>> for RowSlice {
    /// Wrap an owned row (tests / hand-written streams; the driver's
    /// plan path uses [`RowSlice::new`] over a shared tensor buffer).
    fn from(v: Vec<i8>) -> Self {
        let len = v.len();
        Self { buf: Arc::new(v), start: 0, len }
    }
}

impl std::ops::Deref for RowSlice {
    type Target = [i8];

    fn deref(&self) -> &[i8] {
        self.as_slice()
    }
}

/// Identity of a loadable filter set (one tile's weight prologue):
/// dual-basis FNV-1a digests over every payload byte (weights, bias,
/// requant params) plus the layout the PMs were told to interpret it
/// with. Two different filter sets colliding requires a simultaneous
/// 128-bit match. The accelerator compares the resident set's signature
/// against each incoming `LoadWeights` to elide redundant transfers; the
/// coordinator's placement scorer compares the same signatures
/// driver-side (via `driver::plan::CompiledPlan::first_weight_sig`) to
/// steer batches toward the shard whose BRAM already holds their first
/// layer's filters.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WeightSetSig {
    fp: u64,
    fp2: u64,
    count: usize,
    ks: usize,
    ic: usize,
}

impl WeightSetSig {
    /// Signature of `filters` as loaded under a `(ks, ic)` tile layout.
    pub fn of(filters: &[FilterPayload], ks: usize, ic: usize) -> Self {
        let mut fp = Fnv::new();
        let mut fp2 = Fnv::with_basis(Fnv::ALT_BASIS);
        for f in filters {
            for &b in f.weights.iter() {
                fp.byte(b as u8);
                fp2.byte(b as u8);
            }
            for v in [f.bias, f.qmult_m, f.qmult_shift, f.zp_out] {
                fp.word(v as u32 as u64);
                fp2.word(v as u32 as u64);
            }
        }
        Self { fp: fp.finish(), fp2: fp2.finish(), count: filters.len(), ks, ic }
    }

    /// The dual-FNV digest words `(fp, fp2)`. Exposed for the persist
    /// layer's round-trip verification: a plan snapshot records the
    /// words its weight sets were written with, and the loader compares
    /// them against the signature recomputed from the reconstructed
    /// payloads — equality of full [`WeightSetSig`]s stays the semantic
    /// comparison everywhere else.
    pub fn digest_words(&self) -> (u64, u64) {
        (self.fp, self.fp2)
    }

    /// The `(ks, ic)` tile layout the signature was computed under.
    pub fn layout(&self) -> (usize, usize) {
        (self.ks, self.ic)
    }

    /// Filter payloads covered by the signature.
    pub fn filter_count(&self) -> usize {
        self.count
    }
}

/// Per-filter payload of opcode 0x02: the filter tensor slice for one PM,
/// its bias, and the PPU requant parameters (per-channel, as TFLite).
#[derive(Clone, Debug)]
pub struct FilterPayload {
    /// [Ks*Ks*Ic] in (kh, kw, ic) order — the PM-local buffer layout.
    /// `Arc`-shared: plan prologues and PM filter BRAM alias the bytes
    /// packed once at compile time instead of cloning them per stream.
    pub weights: Arc<[i8]>,
    /// Accumulator bias for this output channel.
    pub bias: i32,
    /// Requant multiplier (fixed-point m, shift) and output zero point;
    /// ignored in `OutMode::Raw32`.
    pub qmult_m: i32,
    /// Power-of-two exponent of the requant multiplier.
    pub qmult_shift: i32,
    /// Output zero point applied by the PPU.
    pub zp_out: i32,
}

impl FilterPayload {
    /// Bytes this payload occupies on the weight DMA: the packed filter
    /// plus the 16-byte per-channel header (bias + requant words). The
    /// single source of truth for the simulator's `LoadWeights` transfer
    /// charge and the placement scorer's resident-skip bonus.
    pub fn transfer_bytes(&self) -> u64 {
        self.weights.len() as u64 + 16
    }
}

/// Operand of opcode 0x02: one tile's filter payloads plus the
/// [`WeightSetSig`] precomputed at plan-compile time. The accelerator's
/// resident-skip check compares this signature instead of re-hashing
/// every weight byte on every stream (debug builds re-derive and verify
/// it — a stream carrying a stale signature is a driver bug).
#[derive(Clone, Debug)]
pub struct WeightSet {
    /// One filter payload per PM, index i -> PM i. Private together
    /// with `sig`: the only way to build a `WeightSet` is
    /// [`WeightSet::new`], so a signature can never go stale against
    /// its payloads — the invariant the release-mode resident-skip
    /// comparison in `accel::sim` trusts.
    filters: Vec<FilterPayload>,
    /// Signature of `filters` under the tile's `(ks, ic)` layout.
    sig: WeightSetSig,
}

impl WeightSet {
    /// Bundle `filters` for a `(ks, ic)` tile layout, computing the
    /// resident-set signature once.
    pub fn new(filters: Vec<FilterPayload>, ks: usize, ic: usize) -> Self {
        let sig = WeightSetSig::of(&filters, ks, ic);
        Self { filters, sig }
    }

    /// The per-PM filter payloads.
    pub fn filters(&self) -> &[FilterPayload] {
        &self.filters
    }

    /// The set's resident-set signature (precomputed at construction).
    pub fn sig(&self) -> WeightSetSig {
        self.sig
    }

    /// Total weight-DMA bytes of the set (the sum of
    /// [`FilterPayload::transfer_bytes`]).
    pub fn transfer_bytes(&self) -> u64 {
        self.filters.iter().map(FilterPayload::transfer_bytes).sum()
    }
}

/// A decoded instruction with operands.
#[derive(Clone, Debug)]
pub enum Instr {
    /// Latch one tile's configuration registers.
    Configure(TileConfig),
    /// One filter per PM (index i -> PM i, filter oc_base + i) plus the
    /// set's precomputed resident-set signature.
    LoadWeights(WeightSet),
    /// Input rows starting at `first_row`; each row is a zero-copy
    /// [`RowSlice`] of [Iw*Ic] int8.
    LoadInput {
        /// Index of the first row in the burst.
        first_row: usize,
        /// The row payloads, each [Iw*Ic] bytes, shared not copied.
        rows: Vec<RowSlice>,
    },
    /// Compute one output row on all active PMs.
    Schedule {
        /// Output row index.
        out_row: usize,
    },
    /// Drain the crossbar for one output row back to main memory.
    StoreOutput {
        /// Output row index.
        out_row: usize,
    },
    /// Re-point the output DMA at batch slot `slot`; the input rows of the
    /// slot's request are then streamed fresh. Emitted between the spliced
    /// per-request row schedules of a batched stream so one weight
    /// prologue serves every request in the batch.
    SelectOutput {
        /// Zero-based batch slot (request index within the batch).
        slot: usize,
    },
}

impl Instr {
    /// The wire opcode of this instruction.
    pub fn opcode(&self) -> Opcode {
        match self {
            Instr::Configure(_) => Opcode::Configure,
            Instr::LoadWeights(_) => Opcode::LoadWeights,
            Instr::LoadInput { .. } => Opcode::LoadInput,
            Instr::Schedule { .. } => Opcode::Schedule,
            Instr::StoreOutput { .. } => Opcode::StoreOutput,
            Instr::SelectOutput { .. } => Opcode::SelectOutput,
        }
    }

    /// 32-bit words on the instruction stream (opcode word + operands,
    /// *excluding* bulk data which rides the data AXI channel).
    pub fn encoded_words(&self) -> u64 {
        1 + match self {
            // ih, iw, ic, ks, oc, stride, oc_base, oc_count, mode —
            // the mode word packs out_mode in its low bits and the
            // problem's MapperKind (Overlapped/Segregated walk) as a
            // flag bit, so the per-layer mapper knob costs no extra
            // stream word.
            Instr::Configure(_) => 9,
            // per-filter: bias + qm + shift + zp (weights ride data bus)
            Instr::LoadWeights(ws) => 4 * ws.filters.len() as u64,
            Instr::LoadInput { rows, .. } => 2 + rows.len() as u64, // first,count + per-row len
            Instr::Schedule { .. } => 1,
            Instr::StoreOutput { .. } => 1,
            Instr::SelectOutput { .. } => 1, // output DMA base pointer
        }
    }

    /// Bytes moved on the *data* AXI channel by this instruction.
    pub fn data_bytes(&self) -> u64 {
        match self {
            Instr::LoadWeights(ws) => ws.filters.iter().map(|f| f.weights.len() as u64).sum(),
            Instr::LoadInput { rows, .. } => rows.iter().map(|r| r.len() as u64).sum(),
            _ => 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_opcode_values() {
        assert_eq!(Opcode::Configure as u8, 0x01);
        assert_eq!(Opcode::LoadWeights as u8, 0x02);
        assert_eq!(Opcode::LoadInput as u8, 0x04);
        assert_eq!(Opcode::Schedule as u8, 0x08);
        assert_eq!(Opcode::StoreOutput as u8, 0x10);
        assert_eq!(Opcode::SelectOutput as u8, 0x20);
        for b in [0x01u8, 0x02, 0x04, 0x08, 0x10, 0x20] {
            assert_eq!(Opcode::from_byte(b).unwrap() as u8, b);
        }
        assert!(Opcode::from_byte(0x03).is_none());
        assert!(Opcode::from_byte(0x40).is_none());
    }

    #[test]
    fn tile_validation() {
        let p = TconvProblem::new(4, 4, 8, 3, 16, 2);
        let ok = TileConfig { problem: p, oc_base: 8, oc_count: 8, out_mode: OutMode::Int8 };
        assert!(ok.validate(8).is_ok());
        let too_many = TileConfig { problem: p, oc_base: 0, oc_count: 9, out_mode: OutMode::Int8 };
        assert!(too_many.validate(8).is_err());
        let oob = TileConfig { problem: p, oc_base: 12, oc_count: 8, out_mode: OutMode::Int8 };
        assert!(oob.validate(8).is_err());
    }

    #[test]
    fn encoded_words_and_data_bytes() {
        let li = Instr::LoadInput { first_row: 0, rows: vec![RowSlice::from(vec![0i8; 32]); 3] };
        assert_eq!(li.encoded_words(), 1 + 2 + 3);
        assert_eq!(li.data_bytes(), 96);
        let fp = FilterPayload {
            weights: vec![0i8; 72].into(),
            bias: 0,
            qmult_m: 1,
            qmult_shift: 0,
            zp_out: 0,
        };
        // 72 = Ks*Ks*Ic for (ks, ic) = (3, 8).
        let lw = Instr::LoadWeights(WeightSet::new(vec![fp.clone(), fp], 3, 8));
        assert_eq!(lw.encoded_words(), 1 + 8);
        assert_eq!(lw.data_bytes(), 144);
        assert_eq!(Instr::Schedule { out_row: 5 }.encoded_words(), 2);
        assert_eq!(Instr::Schedule { out_row: 5 }.data_bytes(), 0);
    }

    #[test]
    fn row_slices_share_not_copy() {
        let buf = Arc::new(vec![1i8, 2, 3, 4, 5, 6]);
        let a = RowSlice::new(Arc::clone(&buf), 0, 3);
        let b = RowSlice::new(Arc::clone(&buf), 3, 3);
        assert_eq!(a.as_slice(), &[1, 2, 3]);
        assert_eq!(b.as_slice(), &[4, 5, 6]);
        assert_eq!(b.len(), 3);
        assert!(!b.is_empty());
        assert!(a.shares_buffer(&buf) && b.shares_buffer(&buf));
        // Clones bump the Arc, they do not copy bytes.
        assert!(a.clone().shares_buffer(&buf));
    }

    #[test]
    #[should_panic(expected = "out of bounds")]
    fn row_slice_bounds_checked() {
        let buf = Arc::new(vec![0i8; 4]);
        let _ = RowSlice::new(buf, 2, 3);
    }

    #[test]
    fn weight_set_sig_distinguishes_payloads_and_layout() {
        let fp = |w: Vec<i8>, bias: i32| FilterPayload {
            weights: w.into(),
            bias,
            qmult_m: 1 << 30,
            qmult_shift: 1,
            zp_out: 0,
        };
        let a = WeightSet::new(vec![fp(vec![1, 2, 3, 4], 0)], 1, 4);
        let b = WeightSet::new(vec![fp(vec![1, 2, 3, 4], 0)], 1, 4);
        assert_eq!(a.sig, b.sig, "equal payloads agree");
        let c = WeightSet::new(vec![fp(vec![1, 2, 3, 5], 0)], 1, 4);
        assert_ne!(a.sig, c.sig, "weights differ");
        let d = WeightSet::new(vec![fp(vec![1, 2, 3, 4], 7)], 1, 4);
        assert_ne!(a.sig, d.sig, "bias differs");
        let e = WeightSet::new(vec![fp(vec![1, 2, 3, 4], 0)], 2, 2);
        assert_ne!(a.sig, e.sig, "layout differs");
        assert_eq!(a.transfer_bytes(), 4 + 16);
    }

    /// The persist layer rebuilds a `WeightSet` from its serialized
    /// payloads via `WeightSet::new` and checks the recomputed signature
    /// against the stored digest words — so the accessors must round-trip
    /// exactly through reconstruction.
    #[test]
    fn weight_set_sig_round_trips_through_reconstruction() {
        let fp = |seed: i8| FilterPayload {
            weights: vec![seed, seed + 1, seed + 2, seed + 3].into(),
            bias: seed as i32,
            qmult_m: 1 << 30,
            qmult_shift: 1,
            zp_out: 3,
        };
        let ws = WeightSet::new(vec![fp(1), fp(5)], 1, 4);
        assert_eq!(ws.sig().layout(), (1, 4));
        assert_eq!(ws.sig().filter_count(), 2);
        let (ks, ic) = ws.sig().layout();
        let rebuilt = WeightSet::new(ws.filters().to_vec(), ks, ic);
        assert_eq!(rebuilt.sig(), ws.sig());
        assert_eq!(rebuilt.sig().digest_words(), ws.sig().digest_words());
        // A payload perturbation shows up in the digest words.
        let tampered = WeightSet::new(vec![fp(1), fp(6)], ks, ic);
        assert_ne!(tampered.sig().digest_words(), ws.sig().digest_words());
    }
}
