//! The micro-ISA (Table I of the paper) and its binary encoding.
//!
//! | Opcode | Description                                          |
//! |--------|------------------------------------------------------|
//! | 0x01   | Configure TCONV (sets configuration registers)       |
//! | 0x02   | Loads Bias and Filter (activates Weight Data Loader) |
//! | 0x04   | Load Input (activates Dynamic Input Loader)          |
//! | 0x08   | Schedule TCONV (activates Scheduler)                 |
//! | 0x10   | Store Output (activates Output Crossbar)             |
//! | 0x20   | Select Output slot (driver extension, layer batching) |
//!
//! Instructions are produced by the host driver (`driver::instructions`)
//! and consumed by the simulator's decoder. The typed [`Instr`] carries
//! the operand payload; `encoded_words()` gives the AXI footprint of the
//! same instruction in the wire format (1 opcode word + operand words),
//! which is what the cycle model charges.
//!
//! Opcode 0x20 is not in the paper's Table I: it is the serving layer's
//! extension for weight-reuse batching. It re-points the output DMA base
//! address at another request's output buffer, so one
//! `Configure`/`LoadWeights` prologue per tile can serve a whole batch of
//! inputs (see `driver::plan::CompiledPlan::instantiate_batch`).

use crate::tconv::problem::TconvProblem;

/// Wire-format opcodes (Table I values, plus the 0x20 batching extension).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum Opcode {
    /// 0x01 — set configuration registers for one output-channel tile.
    Configure = 0x01,
    /// 0x02 — load bias + filters (activates the Weight Data Loader).
    LoadWeights = 0x02,
    /// 0x04 — stream input rows (activates the Dynamic Input Loader).
    LoadInput = 0x04,
    /// 0x08 — compute one output row (activates the Scheduler).
    Schedule = 0x08,
    /// 0x10 — drain one output row (activates the Output Crossbar).
    StoreOutput = 0x10,
    /// 0x20 — select the output slot subsequent stores target (driver
    /// extension for weight-reuse layer batching).
    SelectOutput = 0x20,
}

impl Opcode {
    /// Decode a wire byte, `None` for invalid encodings.
    pub fn from_byte(b: u8) -> Option<Self> {
        match b {
            0x01 => Some(Self::Configure),
            0x02 => Some(Self::LoadWeights),
            0x04 => Some(Self::LoadInput),
            0x08 => Some(Self::Schedule),
            0x10 => Some(Self::StoreOutput),
            0x20 => Some(Self::SelectOutput),
            _ => None,
        }
    }
}

/// What the PPU emits: raw int32 accumulators (testing / f32 pipelines
/// quantize later) or requantized int8 (the TFLite integration).
/// `Hash` because the mode is part of the compiled-plan cache key.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum OutMode {
    /// Raw int32 accumulators.
    Raw32,
    /// PPU-requantized int8.
    Int8,
}

/// Operands of opcode 0x01 — one `filter_step` tile of a TCONV layer.
#[derive(Clone, Debug)]
pub struct TileConfig {
    /// Geometry of the *whole* layer (oc = total output channels).
    pub problem: TconvProblem,
    /// First output channel of this tile.
    pub oc_base: usize,
    /// Channels in this tile (<= X; the PMs each take one filter).
    pub oc_count: usize,
    /// Output numeric mode of the PPU.
    pub out_mode: OutMode,
}

impl TileConfig {
    /// Check the tile against the PM-array width and layer geometry.
    pub fn validate(&self, x_pms: usize) -> Result<(), String> {
        if self.oc_count == 0 || self.oc_count > x_pms {
            return Err(format!("oc_count {} exceeds PM array {x_pms}", self.oc_count));
        }
        if self.oc_base + self.oc_count > self.problem.oc {
            return Err(format!(
                "tile [{}, {}) out of range for Oc={}",
                self.oc_base,
                self.oc_base + self.oc_count,
                self.problem.oc
            ));
        }
        Ok(())
    }
}

/// Per-filter payload of opcode 0x02: the filter tensor slice for one PM,
/// its bias, and the PPU requant parameters (per-channel, as TFLite).
#[derive(Clone, Debug)]
pub struct FilterPayload {
    /// [Ks*Ks*Ic] in (kh, kw, ic) order — the PM-local buffer layout.
    pub weights: Vec<i8>,
    /// Accumulator bias for this output channel.
    pub bias: i32,
    /// Requant multiplier (fixed-point m, shift) and output zero point;
    /// ignored in `OutMode::Raw32`.
    pub qmult_m: i32,
    /// Power-of-two exponent of the requant multiplier.
    pub qmult_shift: i32,
    /// Output zero point applied by the PPU.
    pub zp_out: i32,
}

impl FilterPayload {
    /// Bytes this payload occupies on the weight DMA: the packed filter
    /// plus the 16-byte per-channel header (bias + requant words). The
    /// single source of truth for the simulator's `LoadWeights` transfer
    /// charge and the placement scorer's resident-skip bonus.
    pub fn transfer_bytes(&self) -> u64 {
        self.weights.len() as u64 + 16
    }
}

/// A decoded instruction with operands.
#[derive(Clone, Debug)]
pub enum Instr {
    /// Latch one tile's configuration registers.
    Configure(TileConfig),
    /// One filter per PM, index i -> PM i (filter oc_base + i).
    LoadWeights(Vec<FilterPayload>),
    /// Input rows starting at `first_row`; each row is [Iw*Ic] int8.
    LoadInput {
        /// Index of the first row in the burst.
        first_row: usize,
        /// The row payloads, each [Iw*Ic] bytes.
        rows: Vec<Vec<i8>>,
    },
    /// Compute one output row on all active PMs.
    Schedule {
        /// Output row index.
        out_row: usize,
    },
    /// Drain the crossbar for one output row back to main memory.
    StoreOutput {
        /// Output row index.
        out_row: usize,
    },
    /// Re-point the output DMA at batch slot `slot`; the input rows of the
    /// slot's request are then streamed fresh. Emitted between the spliced
    /// per-request row schedules of a batched stream so one weight
    /// prologue serves every request in the batch.
    SelectOutput {
        /// Zero-based batch slot (request index within the batch).
        slot: usize,
    },
}

impl Instr {
    /// The wire opcode of this instruction.
    pub fn opcode(&self) -> Opcode {
        match self {
            Instr::Configure(_) => Opcode::Configure,
            Instr::LoadWeights(_) => Opcode::LoadWeights,
            Instr::LoadInput { .. } => Opcode::LoadInput,
            Instr::Schedule { .. } => Opcode::Schedule,
            Instr::StoreOutput { .. } => Opcode::StoreOutput,
            Instr::SelectOutput { .. } => Opcode::SelectOutput,
        }
    }

    /// 32-bit words on the instruction stream (opcode word + operands,
    /// *excluding* bulk data which rides the data AXI channel).
    pub fn encoded_words(&self) -> u64 {
        1 + match self {
            // ih, iw, ic, ks, oc, stride, oc_base, oc_count, out_mode
            Instr::Configure(_) => 9,
            // per-filter: bias + qm + shift + zp (weights ride data bus)
            Instr::LoadWeights(fs) => 4 * fs.len() as u64,
            Instr::LoadInput { rows, .. } => 2 + rows.len() as u64, // first,count + per-row len
            Instr::Schedule { .. } => 1,
            Instr::StoreOutput { .. } => 1,
            Instr::SelectOutput { .. } => 1, // output DMA base pointer
        }
    }

    /// Bytes moved on the *data* AXI channel by this instruction.
    pub fn data_bytes(&self) -> u64 {
        match self {
            Instr::LoadWeights(fs) => fs.iter().map(|f| f.weights.len() as u64).sum(),
            Instr::LoadInput { rows, .. } => rows.iter().map(|r| r.len() as u64).sum(),
            _ => 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_opcode_values() {
        assert_eq!(Opcode::Configure as u8, 0x01);
        assert_eq!(Opcode::LoadWeights as u8, 0x02);
        assert_eq!(Opcode::LoadInput as u8, 0x04);
        assert_eq!(Opcode::Schedule as u8, 0x08);
        assert_eq!(Opcode::StoreOutput as u8, 0x10);
        assert_eq!(Opcode::SelectOutput as u8, 0x20);
        for b in [0x01u8, 0x02, 0x04, 0x08, 0x10, 0x20] {
            assert_eq!(Opcode::from_byte(b).unwrap() as u8, b);
        }
        assert!(Opcode::from_byte(0x03).is_none());
        assert!(Opcode::from_byte(0x40).is_none());
    }

    #[test]
    fn tile_validation() {
        let p = TconvProblem::new(4, 4, 8, 3, 16, 2);
        let ok = TileConfig { problem: p, oc_base: 8, oc_count: 8, out_mode: OutMode::Int8 };
        assert!(ok.validate(8).is_ok());
        let too_many = TileConfig { problem: p, oc_base: 0, oc_count: 9, out_mode: OutMode::Int8 };
        assert!(too_many.validate(8).is_err());
        let oob = TileConfig { problem: p, oc_base: 12, oc_count: 8, out_mode: OutMode::Int8 };
        assert!(oob.validate(8).is_err());
    }

    #[test]
    fn encoded_words_and_data_bytes() {
        let li = Instr::LoadInput { first_row: 0, rows: vec![vec![0i8; 32]; 3] };
        assert_eq!(li.encoded_words(), 1 + 2 + 3);
        assert_eq!(li.data_bytes(), 96);
        let lw = Instr::LoadWeights(vec![
            FilterPayload { weights: vec![0; 72], bias: 0, qmult_m: 1, qmult_shift: 0, zp_out: 0 };
            2
        ]);
        assert_eq!(lw.encoded_words(), 1 + 8);
        assert_eq!(lw.data_bytes(), 144);
        assert_eq!(Instr::Schedule { out_row: 5 }.encoded_words(), 2);
        assert_eq!(Instr::Schedule { out_row: 5 }.data_bytes(), 0);
    }
}
