//! Data I/O modules (§IV-C): the Weight Data Loader, the Dynamic Input
//! Loader and its Row Buffer.
//!
//! The Row Buffer holds the most recent input rows on-chip; the Dynamic
//! Input Loader appends rows arriving over AXI and evicts the oldest when
//! capacity is exceeded (Algorithm 1 only ever walks forward, so eviction
//! is safe — property-tested against `i_end_row` monotonicity). Rows are
//! stored as shared [`RowSlice`] handles aliasing the instruction
//! stream's (and ultimately the request tensor's) buffer — residency is
//! tracked without copying a byte (§Perf).

use super::isa::RowSlice;
use std::collections::VecDeque;

/// On-chip input Row Buffer.
#[derive(Clone, Debug)]
pub struct RowBuffer {
    rows: VecDeque<(usize, RowSlice)>,
    capacity_rows: usize,
    /// Peak bytes resident (for the BRAM model).
    pub peak_bytes: usize,
}

impl RowBuffer {
    /// Empty buffer bounded at `capacity_rows` resident rows.
    pub fn new(capacity_rows: usize) -> Self {
        assert!(capacity_rows > 0);
        Self { rows: VecDeque::new(), capacity_rows, peak_bytes: 0 }
    }

    /// Drop all resident rows (filter-step / batch-slot boundary).
    pub fn clear(&mut self) {
        self.rows.clear();
    }

    /// Dynamic Input Loader write path (an `Arc` bump, not a byte copy).
    pub fn push(&mut self, row_idx: usize, data: RowSlice) {
        if let Some((last, _)) = self.rows.back() {
            assert!(row_idx > *last, "input rows must arrive in order (got {row_idx} after {last})");
        }
        self.rows.push_back((row_idx, data));
        while self.rows.len() > self.capacity_rows {
            self.rows.pop_front();
        }
        let bytes: usize = self.rows.iter().map(|(_, d)| d.len()).sum();
        self.peak_bytes = self.peak_bytes.max(bytes);
    }

    /// Broadcast read path (Scheduler requests a row for all PMs).
    pub fn get(&self, row_idx: usize) -> Option<&[i8]> {
        self.rows
            .iter()
            .find(|(i, _)| *i == row_idx)
            .map(|(_, d)| d.as_slice())
    }

    /// Rows currently resident.
    pub fn resident_rows(&self) -> usize {
        self.rows.len()
    }

    /// Index of the most recently pushed row.
    pub fn last_row(&self) -> Option<usize> {
        self.rows.back().map(|(i, _)| *i)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_eviction_keeps_recent_rows() {
        let mut rb = RowBuffer::new(3);
        for i in 0..5 {
            rb.push(i, vec![i as i8; 4].into());
        }
        assert_eq!(rb.resident_rows(), 3);
        assert!(rb.get(0).is_none());
        assert!(rb.get(1).is_none());
        assert_eq!(rb.get(2).unwrap(), &[2i8; 4]);
        assert_eq!(rb.get(4).unwrap(), &[4i8; 4]);
        assert_eq!(rb.last_row(), Some(4));
    }

    #[test]
    #[should_panic(expected = "in order")]
    fn rejects_out_of_order_rows() {
        let mut rb = RowBuffer::new(4);
        rb.push(3, vec![0; 2].into());
        rb.push(1, vec![0; 2].into());
    }

    #[test]
    fn peak_bytes_tracked() {
        let mut rb = RowBuffer::new(2);
        rb.push(0, vec![0; 100].into());
        rb.push(1, vec![0; 100].into());
        rb.push(2, vec![0; 100].into()); // evicts row 0
        assert_eq!(rb.peak_bytes, 200);
    }

    #[test]
    fn clear_resets_contents_not_peak() {
        let mut rb = RowBuffer::new(2);
        rb.push(0, vec![0; 10].into());
        rb.clear();
        assert_eq!(rb.resident_rows(), 0);
        assert_eq!(rb.peak_bytes, 10);
        rb.push(0, vec![0; 4].into()); // row indices restart after clear
        assert_eq!(rb.resident_rows(), 1);
    }

    /// Residency tracking must not copy: the resident row aliases the
    /// pushed slice's backing buffer.
    #[test]
    fn rows_resident_without_copy() {
        use std::sync::Arc;
        let buf = Arc::new(vec![7i8; 8]);
        let mut rb = RowBuffer::new(2);
        rb.push(0, RowSlice::new(Arc::clone(&buf), 0, 4));
        assert_eq!(rb.get(0).unwrap().as_ptr(), buf[0..4].as_ptr());
    }
}
