//! The MM2IM Mapper — the hardware module of Algorithm 2 (§IV-E).
//!
//! Generates the compute map (cmap: which weight columns survive) and the
//! output map (omap: which output index each surviving partial
//! accumulates into) *on-chip*, removing the §III-C "up to 35% of
//! latency" omap transfer. Maps are generated once per row and broadcast
//! to all PMs.
//!
//! This is an **independent implementation** of the mapping arithmetic —
//! it does not call `tconv::maps` — so the property tests in
//! `rust/tests/prop_invariants.rs` genuinely cross-check hardware against
//! the software reference.

use super::config::AccelConfig;
use crate::tconv::problem::TconvProblem;

pub use crate::tconv::problem::MapperKind;

/// One surviving tap within an output row's pass over an input row:
/// weight column `kw` (filter row `kh` is fixed per pass) applied to
/// input pixel `iw`, accumulating into output column `ow`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WidthTap {
    /// Input pixel column.
    pub iw: u32,
    /// Weight column within the fixed filter row.
    pub kw: u32,
    /// Output column the partial accumulates into.
    pub ow: u32,
}

/// cmap/omap for one (output row, contributing input row) pass, plus the
/// cycles the mapper spent generating it.
#[derive(Clone, Debug)]
pub struct RowMaps {
    /// The contributing input row.
    pub input_row: usize,
    /// The filter row applied in this pass.
    pub kh: usize,
    /// Surviving width taps, in kw order.
    pub taps: Vec<WidthTap>,
    /// Cycles the mapper spent generating this pass's maps.
    pub mapper_cycles: u64,
    /// Candidate taps the walk presented to the CUs — `Iw * Ks` for the
    /// Overlapped walk, exactly `taps.len()` for the Segregated one (its
    /// sub-kernels contain no croppable positions). The cmap-skip
    /// ablation's wasted-work census is `candidate_taps - taps.len()`.
    pub candidate_taps: u64,
}

/// The Mapper's configuration registers (written by opcode 0x01).
#[derive(Clone, Copy, Debug)]
pub struct Mapper {
    iw: usize,
    ih: usize,
    ks: usize,
    stride: usize,
    pad_top: i64,
    pad_left: i64,
    ow: usize,
    oh: usize,
    kind: MapperKind,
}

impl Mapper {
    /// Latch a problem's geometry into the configuration registers.
    pub fn configure(p: &TconvProblem) -> Self {
        Self {
            iw: p.iw,
            ih: p.ih,
            ks: p.ks,
            stride: p.stride,
            pad_top: p.pad_top() as i64,
            pad_left: p.pad_left() as i64,
            ow: p.ow(),
            oh: p.oh(),
            kind: p.mapper,
        }
    }

    /// The walk this mapper was configured with.
    pub fn kind(&self) -> MapperKind {
        self.kind
    }

    /// Input rows contributing to output row `h`, with their filter row:
    /// the hardware equivalent of Algorithm 1's `i_end_row` walk.
    pub fn contributing_rows(&self, h: usize) -> Vec<(usize, usize)> {
        let mut rows = Vec::with_capacity(self.ks.div_ceil(self.stride));
        for ihr in 0..self.ih {
            let kh = h as i64 + self.pad_top - (ihr * self.stride) as i64;
            if kh >= 0 && (kh as usize) < self.ks {
                rows.push((ihr, kh as usize));
            }
        }
        rows
    }

    /// Generate the width-axis cmap/omap for one (output row, input row)
    /// pass. Both walks emit the *same* taps in the *same* iw-major order
    /// (so numerics and the engine's contiguous kw-groups are identical);
    /// they differ only in cycle cost and candidate census. Overlapped
    /// walks Iw * Ks candidates at `mapper_cycles_per_tap` (Algorithm 2's
    /// inner loop, restricted to the fixed kh of this pass); Segregated
    /// walks only the surviving taps plus a `stride^2` sub-kernel setup.
    pub fn row_maps(&self, input_row: usize, kh: usize, cfg: &AccelConfig) -> RowMaps {
        let mut taps = Vec::with_capacity(self.iw * self.ks);
        for iw in 0..self.iw {
            let w_pad = (iw * self.stride) as i64 - self.pad_left;
            for kw in 0..self.ks {
                let ow = w_pad + kw as i64;
                if ow >= 0 && (ow as usize) < self.ow {
                    taps.push(WidthTap { iw: iw as u32, kw: kw as u32, ow: ow as u32 });
                }
            }
        }
        let walk = self.kind.mapper_walk_slots(self.iw, self.ks, self.stride, taps.len());
        let candidate_taps = self.kind.candidate_taps(self.iw, self.ks, taps.len());
        RowMaps {
            input_row,
            kh,
            taps,
            mapper_cycles: walk * cfg.mapper_cycles_per_tap,
            candidate_taps,
        }
    }

    /// Full Algorithm 2 for one MatMul row (`row_id = ih*Iw + iw`):
    /// emits (col, out) exactly like the paper's listing. Used by the
    /// cross-check tests and by the omap-transfer ablation to size the
    /// transferred map.
    pub fn matmul_row_entries(&self, row_id: usize) -> Vec<(u32, u32)> {
        let h_pad = (self.stride * (row_id / self.iw)) as i64 - self.pad_top;
        let w_pad = (self.stride * (row_id % self.iw)) as i64 - self.pad_left;
        let mut out = Vec::new();
        let mut col = 0u32;
        for kh in 0..self.ks as i64 {
            for kw in 0..self.ks as i64 {
                let oh = kh + h_pad;
                let ow = kw + w_pad;
                if oh >= 0 && (oh as usize) < self.oh && ow >= 0 && (ow as usize) < self.ow {
                    out.push((col, (oh as usize * self.ow + ow as usize) as u32));
                }
                col += 1;
            }
        }
        out
    }

    /// Bytes of omap/cmap data that would cross AXI per MatMul row if the
    /// Mapper did not exist (the §III-C ablation): one packed
    /// (col: u8, out: u24) record per surviving tap — 4 bytes. The map
    /// stream piggybacks the input-row DMA, so it pays payload beats but
    /// no extra descriptor setup.
    pub fn omap_transfer_bytes(&self, row_id: usize) -> u64 {
        self.matmul_row_entries(row_id).len() as u64 * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tconv::maps::for_each_entry;

    #[test]
    fn matmul_row_entries_match_software_maps() {
        for p in [
            TconvProblem::new(2, 2, 2, 3, 2, 1),
            TconvProblem::new(7, 9, 16, 5, 8, 2),
            TconvProblem::new(3, 3, 4, 2, 4, 3),
            TconvProblem::new(1, 1, 21, 4, 21, 4),
        ] {
            let m = Mapper::configure(&p);
            for row in 0..p.m() {
                let mut want = Vec::new();
                for_each_entry(&p, row, |c, o| want.push((c, o)));
                assert_eq!(m.matmul_row_entries(row), want, "{p} row {row}");
            }
        }
    }

    #[test]
    fn row_maps_consistent_with_matmul_entries() {
        // Union over (h, pass) of width taps == union over matmul rows of
        // Algorithm-2 entries, translated.
        let p = TconvProblem::new(4, 5, 3, 5, 2, 2);
        let m = Mapper::configure(&p);
        let mut from_rows: Vec<(usize, usize, usize, usize)> = Vec::new(); // (ihr, iw, kh*ks+kw, out)
        for h in 0..p.oh() {
            for (ihr, kh) in m.contributing_rows(h) {
                let maps = m.row_maps(ihr, kh, &AccelConfig::default());
                for t in maps.taps {
                    from_rows.push((
                        ihr,
                        t.iw as usize,
                        kh * p.ks + t.kw as usize,
                        h * p.ow() + t.ow as usize,
                    ));
                }
            }
        }
        let mut from_matmul: Vec<(usize, usize, usize, usize)> = Vec::new();
        for row in 0..p.m() {
            for (col, out) in m.matmul_row_entries(row) {
                from_matmul.push((row / p.iw, row % p.iw, col as usize, out as usize));
            }
        }
        from_rows.sort_unstable();
        from_matmul.sort_unstable();
        assert_eq!(from_rows, from_matmul);
    }

    #[test]
    fn contributing_rows_mirror_row_schedule() {
        let p = TconvProblem::new(7, 7, 8, 5, 4, 2);
        let m = Mapper::configure(&p);
        let sched = crate::tconv::maps::RowSchedule::build(&p);
        for h in 0..p.oh() {
            assert_eq!(m.contributing_rows(h), sched.contributions[h], "h={h}");
        }
    }

    #[test]
    fn mapper_cycles_charged_per_candidate_tap() {
        let p = TconvProblem::new(4, 6, 8, 3, 4, 1);
        let m = Mapper::configure(&p);
        let maps = m.row_maps(1, 0, &AccelConfig::default());
        assert_eq!(maps.mapper_cycles, (6 * 3) as u64);
        assert_eq!(maps.candidate_taps, (6 * 3) as u64);
    }

    #[test]
    fn segregated_walk_same_taps_fewer_candidates() {
        // ks=5, stride=2 crops aggressively: the Segregated walk must
        // emit the identical tap sequence while presenting only the
        // survivors as candidates and charging survivors + stride^2.
        let p = TconvProblem::new(4, 6, 8, 5, 4, 2);
        let seg = p.with_mapper(MapperKind::Segregated);
        let cfg = AccelConfig::default();
        let (mo, ms) = (Mapper::configure(&p), Mapper::configure(&seg));
        let (a, b) = (mo.row_maps(1, 2, &cfg), ms.row_maps(1, 2, &cfg));
        assert_eq!(a.taps, b.taps, "tap set and order identical across walks");
        assert!(a.taps.len() < p.iw * p.ks, "cropping leaves real waste to elide");
        assert_eq!(b.candidate_taps, b.taps.len() as u64);
        assert_eq!(b.mapper_cycles, (b.taps.len() + 4) as u64);
        assert!(b.mapper_cycles < a.mapper_cycles);
        for h in 0..p.oh() {
            assert_eq!(mo.contributing_rows(h), ms.contributing_rows(h));
        }
    }

    #[test]
    fn omap_transfer_bytes_positive_only_for_survivors() {
        let p = TconvProblem::new(2, 2, 2, 3, 2, 1);
        let m = Mapper::configure(&p);
        // Fig. 2: every row has 4 survivors -> 16 bytes.
        for row in 0..p.m() {
            assert_eq!(m.omap_transfer_bytes(row), 16);
        }
    }
}
