//! Processing Module (Fig. 4): Compute Unit (PE array with cmap-check
//! skip logic, UF-wide MAC unroll over I_c) + Accumulation Unit (out
//! muxer, output row buffer, PPU).
//!
//! Each PM owns one filter at a time (X filters are partitioned across
//! the PM array per Algorithm-1 outer step). `compute_pass` performs one
//! (output row, contributing input row) pass — the Fig. 5 "step"
//! restricted to the taps that land in the current output row — doing the
//! real int8 arithmetic and charging cycles to the CU/AU counters.
//!
//! `compute_pass`/`compute_pass_taps` are the **legacy scalar path**
//! (per-tap dot products), kept as the differential oracle for the fused
//! GEMM+col2IM engine in [`super::engine`] — see
//! `AccelConfig::exec_engine`. Both paths accumulate into the same
//! PM-owned `out_row` buffer and produce bit-identical results and
//! identical cycle charges (`rust/tests/engine_differential.rs`).

use super::config::AccelConfig;
use super::isa::FilterPayload;
use super::mapper::RowMaps;
use crate::tensor::quant::QuantizedMultiplier;
use std::sync::Arc;

/// Cycle counters of one PM (Eq. 3 components).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PmCycles {
    /// CU dot-product cycles.
    pub cu_compute: u64,
    /// CU input-register load cycles.
    pub cu_load: u64,
    /// CU partial-store (CU->AU FIFO) cycles.
    pub cu_store: u64,
    /// Accumulation Unit (out muxer) cycles.
    pub au: u64,
    /// Post-Processing Unit cycles.
    pub ppu: u64,
}

impl PmCycles {
    /// Accumulate another tally into this one.
    pub fn add(&mut self, o: &PmCycles) {
        self.cu_compute += o.cu_compute;
        self.cu_load += o.cu_load;
        self.cu_store += o.cu_store;
        self.au += o.au;
        self.ppu += o.ppu;
    }

    /// T_PM of Eq. 3 (summed component view, as the paper models it).
    pub fn t_pm(&self) -> u64 {
        self.cu_compute + self.cu_load + self.cu_store + self.au + self.ppu
    }
}

/// One Processing Module: CU + AU + PPU around a single resident filter.
pub struct ProcessingModule {
    /// PM-local filter buffer, (kh, kw, ic) order. `Arc`-shared with the
    /// plan's filter payload — loading a filter aliases the compile-time
    /// packed bytes instead of copying them.
    filter: Arc<[i8]>,
    bias: i32,
    qmult: QuantizedMultiplier,
    zp_out: i32,
    /// Output-row accumulator (the "out_buf" — one row, weight/output-
    /// stationary flow sends it back as soon as the row completes).
    out_row: Vec<i32>,
    /// Reusable per-pass pixel-occupancy scratch (which input pixels have
    /// >= 1 surviving tap); hoisted out of `compute_pass_taps` so the hot
    /// loop performs no per-pass allocation.
    pixel_scratch: Vec<bool>,
    ks: usize,
    ic: usize,
    /// Effectual MACs performed (for utilization metrics).
    pub effectual_macs: u64,
    /// MACs that would have been wasted without cmap skip.
    pub skipped_macs: u64,
}

impl Default for ProcessingModule {
    fn default() -> Self {
        Self::new()
    }
}

impl ProcessingModule {
    /// PM with empty filter BRAM and identity requant.
    pub fn new() -> Self {
        Self {
            filter: Arc::new([]),
            bias: 0,
            qmult: QuantizedMultiplier { m: 1 << 30, shift: 1 }, // identity
            zp_out: 0,
            out_row: Vec::new(),
            pixel_scratch: Vec::new(),
            ks: 0,
            ic: 0,
            effectual_macs: 0,
            skipped_macs: 0,
        }
    }

    /// Weight Data Loader target: install one filter (+PPU params). The
    /// filter bytes are shared with the payload (`Arc` bump, no copy).
    pub fn load_filter(&mut self, payload: &FilterPayload, ks: usize, ic: usize) {
        assert_eq!(payload.weights.len(), ks * ks * ic, "filter payload size");
        self.filter = payload.weights.clone();
        self.bias = payload.bias;
        self.qmult = QuantizedMultiplier { m: payload.qmult_m, shift: payload.qmult_shift };
        self.zp_out = payload.zp_out;
        self.ks = ks;
        self.ic = ic;
    }

    /// Begin a new output row of width `ow`: out_buf reset to bias.
    pub fn begin_row(&mut self, ow: usize) {
        self.out_row.clear();
        self.out_row.resize(ow, self.bias);
    }

    /// The in-progress output-row accumulator. The fused engine's col2IM
    /// scatter accumulates GEMM products here — the same buffer the
    /// scalar path's out muxer targets, so both paths are bit-identical
    /// by construction.
    pub(crate) fn row_accum_mut(&mut self) -> &mut [i32] {
        &mut self.out_row
    }

    /// One (output row, input row) pass: dot products of every surviving
    /// (pixel, kw) tap against the PM's filter column (fixed kh),
    /// accumulated via the out muxer into `out_row` at omap positions.
    ///
    /// `input_row` is the broadcast Row Buffer line, `[Iw * Ic]` int8.
    /// Returns the pass's cycle charges.
    pub fn compute_pass(&mut self, input_row: &[i8], maps: &RowMaps, cfg: &AccelConfig) -> PmCycles {
        self.compute_pass_taps(input_row, &maps.taps, maps.kh, maps.candidate_taps, cfg)
    }

    /// Same, with the width-tap map passed directly. The tap set is
    /// invariant across rows (it depends only on Iw/Ks/S/pad), so the
    /// simulator generates it once per tile and broadcasts it — exactly
    /// what the hardware mapper's once-per-row broadcast amortizes
    /// (§Perf: avoids a Vec allocation per pass). `candidate_taps` is
    /// the mapper-walk census the cmap-skip ablation charges against
    /// (`MapperKind::candidate_taps`; the PM itself is mapper-agnostic).
    pub fn compute_pass_taps(
        &mut self,
        input_row: &[i8],
        taps: &[super::mapper::WidthTap],
        kh: usize,
        candidate_taps: u64,
        cfg: &AccelConfig,
    ) -> PmCycles {
        let ic = self.ic;
        debug_assert_eq!(input_row.len() % ic, 0);
        let mut cyc = PmCycles::default();
        // Per-tap dot product: pipeline fill latency + one UF-wide beat
        // per Ic tile. Input streaming costs the same beats again when
        // the PE regs are reloaded per tap.
        let dot = cfg.cu_pipeline_latency + cfg.dot_cycles(ic);
        let load = cfg.dot_cycles(ic);

        if !cfg.cu_reload_input_per_tap {
            // pixel loaded once per pass per pixel that has >=1 surviving
            // tap; the occupancy scratch is PM-owned and recycled.
            self.pixel_scratch.clear();
            self.pixel_scratch.resize(input_row.len() / ic, false);
            for t in taps {
                self.pixel_scratch[t.iw as usize] = true;
            }
            cyc.cu_load += self.pixel_scratch.iter().filter(|&&b| b).count() as u64 * load;
        }

        for t in taps {
            let x = &input_row[t.iw as usize * ic..(t.iw as usize + 1) * ic];
            let w0 = (kh * self.ks + t.kw as usize) * ic;
            let w = &self.filter[w0..w0 + ic];
            // Plain zipped dot: LLVM auto-vectorizes the widening i8
            // multiply-accumulate better than a hand-unrolled version
            // (measured; see EXPERIMENTS.md §Perf iteration log).
            let acc: i32 = x.iter().zip(w).map(|(&xv, &wv)| xv as i32 * wv as i32).sum();
            // out muxer: accumulate at the omap target (overlapping sums
            // coalesce here — no temporary partial storage).
            self.out_row[t.ow as usize] += acc;

            cyc.cu_compute += dot;
            if cfg.cu_reload_input_per_tap {
                cyc.cu_load += load;
            }
            cyc.cu_store += 1; // partial into the CU->AU FIFO
            cyc.au += 1; // muxer accumulate
            self.effectual_macs += ic as u64;
        }

        if !cfg.cmap_skip_enabled {
            // Ablation: the baseline-IOM CU computes cropped taps too and
            // the AU discards them — charge their cycles, count the waste.
            // Under the Segregated walk `candidate_taps == taps.len()`:
            // ineffectual positions never exist, so there is no waste to
            // restore.
            let w64 = candidate_taps - taps.len() as u64;
            cyc.cu_compute += w64 * dot;
            if cfg.cu_reload_input_per_tap {
                cyc.cu_load += w64 * load;
            }
            cyc.cu_store += w64;
            cyc.au += w64;
            self.skipped_macs += w64 * ic as u64;
        }
        cyc
    }

    /// Row complete: PPU post-processes into caller-recycled buffers and
    /// drains the accumulator (no allocation, no copy — the accumulator
    /// is swapped out and its old storage becomes the next row's buffer).
    /// `raw`/`quant` are cleared and refilled. Returns the PPU cycle
    /// charge.
    pub fn finish_row_into(
        &mut self,
        cfg: &AccelConfig,
        raw: &mut Vec<i32>,
        quant: &mut Vec<i8>,
    ) -> u64 {
        raw.clear();
        std::mem::swap(&mut self.out_row, raw);
        quant.clear();
        quant.extend(
            raw.iter().map(|&acc| (self.qmult.apply(acc) + self.zp_out).clamp(-128, 127) as i8),
        );
        raw.len() as u64 * cfg.ppu_cycles_per_output + cfg.fifo_drain_cycles
    }

    /// Row complete: PPU post-processes and streams to the crossbar.
    /// Returns (raw accumulators, requantized int8, ppu cycle charge).
    /// Drains the accumulator; allocation-free callers use
    /// [`ProcessingModule::finish_row_into`].
    pub fn finish_row(&mut self, cfg: &AccelConfig) -> (Vec<i32>, Vec<i8>, u64) {
        let (mut raw, mut quant) = (Vec::new(), Vec::new());
        let ppu = self.finish_row_into(cfg, &mut raw, &mut quant);
        (raw, quant, ppu)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::mapper::Mapper;
    use crate::tconv::problem::TconvProblem;
    use crate::util::rng::Pcg32;

    fn payload(p: &TconvProblem, oc: usize, w: &crate::tensor::Tensor<i8>, bias: i32) -> FilterPayload {
        let mut weights = Vec::with_capacity(p.ks * p.ks * p.ic);
        for kh in 0..p.ks {
            for kw in 0..p.ks {
                for c in 0..p.ic {
                    weights.push(w.at4(oc, kh, kw, c));
                }
            }
        }
        FilterPayload { weights: weights.into(), bias, qmult_m: 1 << 30, qmult_shift: 1, zp_out: 0 }
    }

    /// One PM computing one full output channel row-by-row must equal the
    /// reference accumulators for that channel.
    #[test]
    fn pm_reproduces_reference_channel() {
        let p = TconvProblem::new(5, 4, 8, 5, 3, 2);
        let mut rng = Pcg32::new(77);
        let x = crate::tensor::Tensor::<i8>::random(&[p.ih, p.iw, p.ic], &mut rng);
        let w = crate::tensor::Tensor::<i8>::random(&[p.oc, p.ks, p.ks, p.ic], &mut rng);
        let want = crate::tconv::reference::direct_i32(&p, &x, &w, None);

        let cfg = AccelConfig::default();
        let mapper = Mapper::configure(&p);
        for oc in 0..p.oc {
            let mut pm = ProcessingModule::new();
            pm.load_filter(&payload(&p, oc, &w, 0), p.ks, p.ic);
            for h in 0..p.oh() {
                pm.begin_row(p.ow());
                for (ihr, kh) in mapper.contributing_rows(h) {
                    let row = &x.data()[ihr * p.iw * p.ic..(ihr + 1) * p.iw * p.ic];
                    let maps = mapper.row_maps(ihr, kh, &cfg);
                    pm.compute_pass(row, &maps, &cfg);
                }
                let (raw, _q, _ppu) = pm.finish_row(&cfg);
                for ow in 0..p.ow() {
                    assert_eq!(
                        raw[ow],
                        want.at3(h, ow, oc),
                        "oc={oc} h={h} ow={ow}"
                    );
                }
            }
        }
    }

    #[test]
    fn bias_initializes_accumulator() {
        let p = TconvProblem::new(2, 2, 4, 3, 1, 1);
        let mut rng = Pcg32::new(1);
        let w = crate::tensor::Tensor::<i8>::random(&[1, 3, 3, 4], &mut rng);
        let mut pm = ProcessingModule::new();
        pm.load_filter(&payload(&p, 0, &w, 1000), p.ks, p.ic);
        pm.begin_row(p.ow());
        let (raw, _, _) = pm.finish_row(&AccelConfig::default());
        assert!(raw.iter().all(|&v| v == 1000));
    }

    #[test]
    fn cycle_charges_scale_with_ic_and_taps() {
        let p = TconvProblem::new(2, 4, 32, 3, 1, 1);
        let mut rng = Pcg32::new(2);
        let x = crate::tensor::Tensor::<i8>::random(&[p.ih, p.iw, p.ic], &mut rng);
        let w = crate::tensor::Tensor::<i8>::random(&[1, 3, 3, 32], &mut rng);
        let cfg = AccelConfig::default();
        let mapper = Mapper::configure(&p);
        let mut pm = ProcessingModule::new();
        pm.load_filter(&payload(&p, 0, &w, 0), p.ks, p.ic);
        pm.begin_row(p.ow());
        let (ihr, kh) = mapper.contributing_rows(0)[0];
        let maps = mapper.row_maps(ihr, kh, &cfg);
        let cyc = pm.compute_pass(&x.data()[ihr * p.iw * p.ic..(ihr + 1) * p.iw * p.ic], &maps, &cfg);
        let taps = maps.taps.len() as u64;
        // per tap: pipeline latency 10 + ceil(32/16)=2 beats = 12 cycles.
        assert_eq!(cyc.cu_compute, taps * 12);
        assert_eq!(cyc.cu_load, taps * 2); // reload per tap (default)
        assert_eq!(cyc.cu_store, taps);
        assert_eq!(cyc.au, taps);
        assert_eq!(pm.effectual_macs, taps * 32);
    }

    #[test]
    fn cmap_skip_ablation_charges_wasted_cycles_same_numerics() {
        let p = TconvProblem::new(3, 3, 16, 5, 1, 1); // heavy cropping
        let mut rng = Pcg32::new(3);
        let x = crate::tensor::Tensor::<i8>::random(&[p.ih, p.iw, p.ic], &mut rng);
        let w = crate::tensor::Tensor::<i8>::random(&[1, 5, 5, 16], &mut rng);
        let mapper = Mapper::configure(&p);

        let run = |cfg: &AccelConfig| {
            let mut pm = ProcessingModule::new();
            pm.load_filter(&payload(&p, 0, &w, 0), p.ks, p.ic);
            let mut total = PmCycles::default();
            let mut rows = Vec::new();
            for h in 0..p.oh() {
                pm.begin_row(p.ow());
                for (ihr, kh) in mapper.contributing_rows(h) {
                    let row = &x.data()[ihr * p.iw * p.ic..(ihr + 1) * p.iw * p.ic];
                    total.add(&pm.compute_pass(row, &mapper.row_maps(ihr, kh, cfg), cfg));
                }
                rows.push(pm.finish_row(cfg).0);
            }
            (total, rows)
        };

        let with_skip = run(&AccelConfig::default());
        let mut no_skip_cfg = AccelConfig::default();
        no_skip_cfg.cmap_skip_enabled = false;
        let without = run(&no_skip_cfg);

        assert_eq!(with_skip.1, without.1, "numerics must not change");
        assert!(without.0.cu_compute > with_skip.0.cu_compute, "ablation must cost more");
    }

    #[test]
    fn requant_path_applies_multiplier() {
        let p = TconvProblem::new(1, 1, 4, 1, 1, 1);
        let w = crate::tensor::Tensor::from_vec(&[1, 1, 1, 4], vec![1i8, 1, 1, 1]);
        let mut pm = ProcessingModule::new();
        let mut pl = payload(&p, 0, &w, 0);
        // multiplier = 0.5: m = 2^30, shift = 0
        pl.qmult_m = 1 << 30;
        pl.qmult_shift = 0;
        pl.zp_out = 3;
        pm.load_filter(&pl, 1, 4);
        pm.begin_row(1);
        let x = [10i8, 10, 10, 10];
        let mapper = Mapper::configure(&p);
        let maps = mapper.row_maps(0, 0, &AccelConfig::default());
        pm.compute_pass(&x, &maps, &AccelConfig::default());
        let (raw, q, _) = pm.finish_row(&AccelConfig::default());
        assert_eq!(raw[0], 40);
        assert_eq!(q[0], 23); // 40 * 0.5 + 3
    }

    /// `finish_row_into` recycles caller buffers: the drained accumulator
    /// is handed back without copying, and the next row reuses its
    /// capacity through `begin_row`.
    #[test]
    fn finish_row_into_recycles_buffers() {
        let p = TconvProblem::new(2, 2, 4, 3, 1, 1);
        let mut rng = Pcg32::new(4);
        let w = crate::tensor::Tensor::<i8>::random(&[1, 3, 3, 4], &mut rng);
        let mut pm = ProcessingModule::new();
        pm.load_filter(&payload(&p, 0, &w, 5), p.ks, p.ic);
        let (mut raw, mut quant) = (vec![99i32; 3], vec![9i8; 3]);
        pm.begin_row(p.ow());
        let ppu = pm.finish_row_into(&AccelConfig::default(), &mut raw, &mut quant);
        assert_eq!(raw, vec![5i32; p.ow()], "bias-initialized row handed back");
        assert_eq!(quant.len(), p.ow());
        let cfg = AccelConfig::default();
        assert_eq!(ppu, p.ow() as u64 * cfg.ppu_cycles_per_output + cfg.fifo_drain_cycles);
        // Second row with the same buffers must be identical (stale
        // contents from the first call must not leak through).
        pm.begin_row(p.ow());
        let (raw2, quant2, _) = pm.finish_row(&cfg);
        pm.begin_row(p.ow());
        pm.finish_row_into(&cfg, &mut raw, &mut quant);
        assert_eq!(raw, raw2);
        assert_eq!(quant, quant2);
    }
}
