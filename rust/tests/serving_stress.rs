//! Concurrency stress for the serving subsystem: many workers x many
//! shards against a deliberately tiny bounded queue, so submission
//! backpressure engages constantly. Asserts no deadlock (the test
//! completes), every ticket resolved exactly once, ids sorted after
//! `drain`, and that the shared plan cache compiled each layer exactly
//! once for the whole run — then repeats the exercise with concurrent
//! cancellations and lapsed deadlines in the mix, asserting the
//! exactly-once ledger still balances
//! (`served + cancelled + deadline_expired == submitted`).
//!
//! The `chaos_`-prefixed leg re-runs the exercise under seeded random
//! fault plans on a heterogeneous fleet: the ledger grows a `failed`
//! term (`served + cancelled + deadline_expired + failed == submitted`)
//! and every survivor must stay byte-identical to a fault-free run of
//! the same seed. CI runs this leg by name under its `MM2IM_FAULT_SPEC`
//! matrix; the plans here are installed explicitly per trial, so the
//! leg is deterministic either way.

use mm2im::accel::{AccelConfig, FaultPlan, FaultSpec};
use mm2im::bench::workloads::hetero_fleet;
use mm2im::coordinator::{Outcome, Priority, Request, Server, Ticket};
use mm2im::driver::Delegate;
use mm2im::model::executor::Executor;
use mm2im::model::graph::Layer;
use mm2im::model::zoo;
use mm2im::tensor::Tensor;
use mm2im::util::rng::Pcg32;
use std::sync::Arc;
use std::time::Duration;

#[test]
fn stress_shards_workers_backpressure_exactly_once() {
    let graph = Arc::new(zoo::pix2pix(8, 2, 0));
    let tconv_layers =
        graph.layers.iter().filter(|l| matches!(l, Layer::Tconv { .. })).count() as u64;
    assert!(tconv_layers >= 2);

    let queue_capacity = 4;
    let mut server = Server::builder()
        .graph(graph)
        .shards(4)
        .workers_per_shard(2)
        .queue_capacity(queue_capacity)
        .max_batch(3)
        .start()
        .expect("valid config");

    let total = 64u64;
    let mut collected = Vec::new();
    for i in 0..total {
        // Repeating seeds: realistic duplicate traffic; ids stay unique.
        let ticket = server.submit(Request::seed(i % 7)).expect("seeded submit");
        assert_eq!(ticket.id(), i);
        // Bounded-queue invariant holds at every step (this is what
        // `submit` blocking on a full queue guarantees).
        assert!(server.queued() <= queue_capacity, "queue overflow at i={i}");
        if i % 9 == 0 {
            collected.extend(server.poll());
        }
    }

    let (rest, stats) = server.finish();
    // Ids sorted after drain.
    assert!(rest.windows(2).all(|w| w[0].id < w[1].id), "drain not sorted");

    // Every response exactly once across poll windows + drain.
    collected.extend(rest);
    let mut ids: Vec<u64> = collected.iter().map(|r| r.id).collect();
    ids.sort_unstable();
    assert_eq!(ids, (0..total).collect::<Vec<u64>>(), "lost or duplicated responses");
    assert!(collected.iter().all(|r| r.outcome == Outcome::Ok));

    // Same seed => same bytes, no matter which shard/worker served it.
    for a in &collected {
        for b in &collected {
            if a.seed() == b.seed() {
                assert_eq!(
                    a.output_tensor().data(),
                    b.output_tensor().data(),
                    "seed {:?} diverged",
                    a.seed()
                );
            }
        }
    }

    // Server-lifetime stats are complete and consistent.
    assert_eq!(stats.requests, total);
    assert_eq!(stats.submitted, total);
    assert_eq!((stats.cancelled, stats.deadline_expired), (0, 0));
    assert_eq!(stats.shard_utilization.len(), 4);
    assert_eq!(stats.shard_requests.iter().sum::<u64>(), total);
    assert!(stats.batches > 0 && stats.mean_batch_size >= 1.0);
    assert!(stats.p95_latency_s >= stats.p50_latency_s);

    // The whole 8-worker run compiled each TCONV layer exactly once
    // (compilation happens under the cache lock), everything else hit.
    // Layer batching looks each plan up once per (batch, layer).
    assert_eq!(stats.cache_misses, tconv_layers);
    assert_eq!(stats.cache_hits + stats.cache_misses, stats.batches * tconv_layers);

    // Weight-load accounting: batching can only reduce loads, never
    // inflate them past the per-request equivalent.
    assert!(stats.weight_loads > 0);
    assert!(stats.weight_loads <= stats.weight_loads_equiv);
    let rate = stats.weight_load_hit_rate();
    assert!((0.0..1.0).contains(&rate), "weight hit rate {rate}");
}

/// Cancellation + deadlines under concurrent load: tickets cancelled
/// from a second thread while workers drain, plus a slice of requests
/// with already-lapsed deadlines. Every ticket resolves to exactly one
/// outcome and the stats ledger balances.
#[test]
fn stress_cancellation_and_deadlines_exactly_once() {
    let graph = Arc::new(zoo::pix2pix(8, 2, 0));
    let mut server = Server::builder()
        .graph(graph)
        .shards(2)
        .workers_per_shard(2)
        .queue_capacity(8)
        .max_batch(3)
        .start()
        .expect("valid config");

    let total = 48u64;
    let mut cancel_tickets: Vec<Ticket> = Vec::new();
    let mut expired_ids = Vec::new();
    for i in 0..total {
        let req = match i % 4 {
            // Background traffic we will try to cancel from another
            // thread while workers race us for it.
            0 => Request::seed(i).priority(Priority::Low),
            // Already-lapsed deadline: must drop at batch formation if a
            // worker doesn't... (it can't — sweep runs before take).
            1 => {
                expired_ids.push(i);
                Request::seed(i).deadline(Duration::ZERO)
            }
            // Generous deadline: must always survive to execution.
            2 => Request::seed(i).deadline(Duration::from_secs(3600)),
            _ => Request::seed(i).priority(Priority::High),
        };
        let ticket = server.submit(req).expect("seeded submit");
        if i % 4 == 0 {
            cancel_tickets.push(ticket);
        }
    }

    // Race cancellations against the draining workers; each cancel is
    // atomic — it either removed the queued request (true) or lost the
    // race to a batch (false) — never both.
    let cancel_results: Vec<(u64, bool)> = {
        let handle = std::thread::spawn(move || {
            cancel_tickets.into_iter().map(|t| (t.id(), t.cancel())).collect::<Vec<_>>()
        });
        handle.join().expect("cancel thread")
    };

    let (responses, stats) = server.finish();
    assert_eq!(responses.len(), total as usize, "every ticket resolves exactly once");
    assert_eq!(
        responses.iter().map(|r| r.id).collect::<Vec<u64>>(),
        (0..total).collect::<Vec<u64>>()
    );

    // The outcome ledger balances exactly.
    let served = responses.iter().filter(|r| r.outcome == Outcome::Ok).count() as u64;
    let cancelled = responses.iter().filter(|r| r.outcome == Outcome::Cancelled).count() as u64;
    let expired = responses.iter().filter(|r| r.outcome == Outcome::DeadlineExpired).count() as u64;
    assert_eq!(served + cancelled + expired, total);
    assert_eq!(stats.requests, served);
    assert_eq!(stats.cancelled, cancelled);
    assert_eq!(stats.deadline_expired, expired);
    assert_eq!(stats.submitted, total);

    // A cancel that returned true resolved as Cancelled; one that lost
    // the race resolved as Ok (Low-priority requests carried no
    // deadline, so nothing else can have claimed them).
    for (id, won) in cancel_results {
        let r = &responses[id as usize];
        let want = if won { Outcome::Cancelled } else { Outcome::Ok };
        assert_eq!(r.outcome, want, "ticket {id} (cancel returned {won})");
    }

    // Zero-deadline requests can only be served or expired — and served
    // only if a worker batched them before their first sweep, which a
    // `Duration::ZERO` deadline makes impossible (the sweep precedes
    // every batch formation).
    for id in expired_ids {
        assert_eq!(
            responses[id as usize].outcome,
            Outcome::DeadlineExpired,
            "zero-deadline request {id} must drop at batch formation"
        );
    }

    // Generous-deadline requests always executed.
    for r in responses.iter().filter(|r| r.id % 4 == 2) {
        assert_eq!(r.outcome, Outcome::Ok, "id {}", r.id);
        assert!(r.output.is_some());
    }

    // Unserved requests never contribute execution time or a shard.
    for r in responses.iter().filter(|r| r.outcome != Outcome::Ok) {
        assert!(r.output.is_none());
        assert_eq!(r.shard, None);
        assert_eq!(r.wall_seconds, 0.0);
    }
}

/// Chaos stress: random (but seeded, hence replayable) fault mixes over
/// a heterogeneous two-shard fleet with backpressure engaged. Faults
/// must never break the serving contracts: every ticket resolves
/// exactly once, the four-term ledger balances, survivors are
/// byte-identical to a fault-free run of the same seeds, and no worker
/// thread dies (these plans inject execution faults, not aborts).
#[test]
fn chaos_random_fault_plans_hold_exactly_once() {
    let graph = Arc::new(zoo::pix2pix(8, 2, 0));

    // Fault-free reference bytes per request seed (the traffic below
    // reuses seeds 0..5). Heterogeneity is irrelevant to numerics —
    // placement tests pin that — so one default-config executor serves
    // as the oracle for every shard.
    let reference = Executor::new(Delegate::new(AccelConfig::default(), 1, true));
    let want: Vec<Vec<i8>> = (0..5u64)
        .map(|seed| {
            let mut rng = Pcg32::new(seed);
            let input = Tensor::<i8>::random(&graph.input_shape, &mut rng);
            reference.run(&graph, &input).output.data().to_vec()
        })
        .collect();

    let mut entropy = Pcg32::new(0xC4A05EED);
    for trial in 0..4u64 {
        let spec = FaultSpec::new(900 + trial)
            .transient(entropy.f32() as f64 * 0.2)
            .corrupt(entropy.f32() as f64 * 0.2)
            .stall(entropy.f32() as f64 * 0.2, 1);
        let mut server = Server::builder()
            .graph(graph.clone())
            .workers_per_shard(2)
            .queue_capacity(8)
            .max_batch(3)
            .shard_fleet(hetero_fleet())
            .fault_plan(FaultPlan::new(spec.clone()))
            .retry_budget(3)
            .start()
            .expect("valid config");

        let total = 24u64;
        for i in 0..total {
            // Blocking submits against the small queue: backpressure
            // and fault-triggered requeues interleave constantly.
            server.submit(Request::seed(i % 5)).expect("seeded submit");
        }
        let (responses, stats) = server.finish();

        // Exactly once, whatever the faults did.
        assert_eq!(responses.len(), total as usize, "plan [{spec}]");
        let mut ids: Vec<u64> = responses.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..total).collect::<Vec<u64>>(), "plan [{spec}]");

        // The four-term ledger balances exactly.
        let served = responses.iter().filter(|r| r.outcome == Outcome::Ok).count() as u64;
        let failed =
            responses.iter().filter(|r| matches!(r.outcome, Outcome::Failed(_))).count() as u64;
        assert_eq!(served + failed, total, "plan [{spec}]: no cancels/deadlines in this leg");
        assert_eq!(
            stats.requests + stats.cancelled + stats.deadline_expired + stats.requests_failed,
            stats.submitted,
            "plan [{spec}]: {stats:?}"
        );
        assert_eq!(stats.requests, served, "plan [{spec}]");
        assert_eq!(stats.requests_failed, failed, "plan [{spec}]");
        assert!(stats.worker_failures.is_empty(), "plan [{spec}] kills no workers");

        // Retries happened iff executions failed, and survivors carry
        // exactly the fault-free bytes for their seed.
        if stats.requests_failed > 0 {
            assert!(stats.exec_failures > 0, "plan [{spec}]");
        }
        for r in responses.iter().filter(|r| r.outcome == Outcome::Ok) {
            let seed = r.seed().expect("seeded") as usize;
            assert_eq!(
                r.output_tensor().data(),
                &want[seed][..],
                "plan [{spec}] id {} seed {seed} diverged from fault-free bytes",
                r.id
            );
        }
        for r in responses.iter().filter(|r| r.outcome != Outcome::Ok) {
            assert!(r.output.is_none() && r.shard.is_none(), "plan [{spec}] id {}", r.id);
        }
    }
}

#[test]
fn pause_resume_under_load_loses_nothing() {
    let graph = Arc::new(zoo::pix2pix(8, 2, 0));
    let mut server = Server::builder()
        .graph(graph)
        .shards(2)
        .workers_per_shard(1)
        .queue_capacity(8)
        .max_batch(2)
        .start()
        .expect("valid config");
    let mut ids = Vec::new();
    // 4 rounds x 2 submissions = 8 = queue capacity: even if paused
    // workers never drain a single request, the blocking `submit` can
    // always admit the burst — no self-deadlock by construction.
    for round in 0..4u64 {
        server.pause();
        for k in 0..2u64 {
            ids.push(server.submit(Request::seed(round * 2 + k)).expect("submit").id());
        }
        server.resume();
    }
    let responses = server.drain();
    assert_eq!(responses.len(), 8);
    let got: Vec<u64> = responses.iter().map(|r| r.id).collect();
    assert_eq!(got, (0..8).collect::<Vec<u64>>());
    assert_eq!(ids, (0..8).collect::<Vec<u64>>());
}
