//! Concurrency stress for the serving subsystem: many workers x many
//! shards against a deliberately tiny bounded queue, so submission
//! backpressure engages constantly. Asserts no deadlock (the test
//! completes), every response returned exactly once, ids sorted after
//! `drain`, and that the shared plan cache compiled each layer exactly
//! once for the whole run.

use mm2im::coordinator::{Server, ServerConfig};
use mm2im::model::graph::Layer;
use mm2im::model::zoo;
use std::sync::Arc;

#[test]
fn stress_shards_workers_backpressure_exactly_once() {
    let graph = Arc::new(zoo::pix2pix(8, 2, 0));
    let tconv_layers =
        graph.layers.iter().filter(|l| matches!(l, Layer::Tconv { .. })).count() as u64;
    assert!(tconv_layers >= 2);

    let queue_capacity = 4;
    let config = ServerConfig {
        shards: 4,
        workers_per_shard: 2,
        queue_capacity,
        max_batch: 3,
        ..ServerConfig::default()
    };
    let mut server = Server::start(graph, config);

    let total = 64u64;
    let mut collected = Vec::new();
    for i in 0..total {
        // Repeating seeds: realistic duplicate traffic; ids stay unique.
        let id = server.submit(i % 7);
        assert_eq!(id, i);
        // Bounded-queue invariant holds at every step (this is what
        // `submit` blocking on a full queue guarantees).
        assert!(server.queued() <= queue_capacity, "queue overflow at i={i}");
        if i % 9 == 0 {
            collected.extend(server.poll());
        }
    }

    let (rest, stats) = server.finish();
    // Ids sorted after drain.
    assert!(rest.windows(2).all(|w| w[0].id < w[1].id), "drain not sorted");

    // Every response exactly once across poll windows + drain.
    collected.extend(rest);
    let mut ids: Vec<u64> = collected.iter().map(|r| r.id).collect();
    ids.sort_unstable();
    assert_eq!(ids, (0..total).collect::<Vec<u64>>(), "lost or duplicated responses");

    // Same seed => same bytes, no matter which shard/worker served it.
    for a in &collected {
        for b in &collected {
            if a.seed == b.seed {
                assert_eq!(a.output.data(), b.output.data(), "seed {} diverged", a.seed);
            }
        }
    }

    // Server-lifetime stats are complete and consistent.
    assert_eq!(stats.requests, total as usize);
    assert_eq!(stats.submitted, total);
    assert_eq!(stats.shard_utilization.len(), 4);
    assert_eq!(stats.shard_requests.iter().sum::<u64>(), total);
    assert!(stats.batches > 0 && stats.mean_batch_size >= 1.0);
    assert!(stats.p95_latency_s >= stats.p50_latency_s);

    // The whole 8-worker run compiled each TCONV layer exactly once
    // (compilation happens under the cache lock), everything else hit.
    // Layer batching looks each plan up once per (batch, layer).
    assert_eq!(stats.cache_misses, tconv_layers);
    assert_eq!(stats.cache_hits + stats.cache_misses, stats.batches * tconv_layers);

    // Weight-load accounting: batching can only reduce loads, never
    // inflate them past the per-request equivalent.
    assert!(stats.weight_loads > 0);
    assert!(stats.weight_loads <= stats.weight_loads_equiv);
    let rate = stats.weight_load_hit_rate();
    assert!((0.0..1.0).contains(&rate), "weight hit rate {rate}");
}

#[test]
fn pause_resume_under_load_loses_nothing() {
    let graph = Arc::new(zoo::pix2pix(8, 2, 0));
    let config = ServerConfig {
        shards: 2,
        workers_per_shard: 1,
        queue_capacity: 8,
        max_batch: 2,
        ..ServerConfig::default()
    };
    let mut server = Server::start(graph, config);
    let mut ids = Vec::new();
    // 4 rounds x 2 submissions = 8 = queue capacity: even if paused
    // workers never drain a single request, the blocking `submit` can
    // always admit the burst — no self-deadlock by construction.
    for round in 0..4u64 {
        server.pause();
        for k in 0..2u64 {
            ids.push(server.submit(round * 2 + k));
        }
        server.resume();
    }
    let responses = server.drain();
    assert_eq!(responses.len(), 8);
    let got: Vec<u64> = responses.iter().map(|r| r.id).collect();
    assert_eq!(got, (0..8).collect::<Vec<u64>>());
    assert_eq!(ids, (0..8).collect::<Vec<u64>>());
}
