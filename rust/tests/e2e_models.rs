//! End-to-end model tests (§V-E methodology): full GAN graphs run through
//! the delegate with real int8 numerics; accelerator and CPU paths must
//! agree byte-for-byte, and the Table IV performance ratios must land in
//! the paper's bands.

use mm2im::accel::AccelConfig;
use mm2im::driver::Delegate;
use mm2im::model::executor::{Executor, RunConfig, Work};
use mm2im::model::zoo;
use mm2im::tensor::Tensor;
use mm2im::util::rng::Pcg32;

fn run_both(g: &mm2im::model::Graph, seed: u64) -> (Vec<i8>, Vec<i8>) {
    let mut rng = Pcg32::new(seed);
    let input = Tensor::<i8>::random(&g.input_shape, &mut rng);
    let acc = Executor::new(Delegate::new(AccelConfig::default(), 2, true));
    let cpu = Executor::new(Delegate::new(AccelConfig::default(), 1, false));
    (
        acc.run(g, &input).output.into_vec(),
        cpu.run(g, &input).output.into_vec(),
    )
}

#[test]
fn dcgan_accelerated_equals_cpu_only() {
    let g = zoo::dcgan_tf(0);
    for seed in [1u64, 2, 3] {
        let (a, c) = run_both(&g, seed);
        assert_eq!(a, c, "seed {seed}");
    }
}

#[test]
fn pix2pix_accelerated_equals_cpu_only() {
    let g = zoo::pix2pix(64, 16, 0);
    let (a, c) = run_both(&g, 9);
    assert_eq!(a, c);
}

#[test]
fn fsrcnn_accelerated_equals_cpu_only() {
    let g = zoo::fsrcnn(16, 0);
    let (a, c) = run_both(&g, 4);
    assert_eq!(a, c);
}

/// Table IV ratios for DCGAN: ACC+CPU must beat CPU-only on TCONV time,
/// overall time, and energy; 2T CPU sits between.
#[test]
fn dcgan_table4_ratio_bands() {
    let g = zoo::dcgan_tf(0);
    let mut rng = Pcg32::new(31);
    let input = Tensor::<i8>::random(&g.input_shape, &mut rng);
    let exec = Executor::new(Delegate::new(AccelConfig::default(), 2, true));
    let run = exec.run(&g, &input);
    let cfg = AccelConfig::default();

    let cpu1 = run.modeled(RunConfig::Cpu { threads: 1 }, &cfg);
    let cpu2 = run.modeled(RunConfig::Cpu { threads: 2 }, &cfg);
    let acc1 = run.modeled(RunConfig::AccPlusCpu { threads: 1 }, &cfg);
    let acc2 = run.modeled(RunConfig::AccPlusCpu { threads: 2 }, &cfg);

    // paper Table IV (DCGAN): TCONV speedups 1.0 / 2.4 / 1.6 / 2.4,
    // overall 1.0 / 2.3 / 1.7 / 2.4, energy 1.0 / 1.8 / 1.2 / 1.8.
    // (our simulator runs the big-Ic TF-tutorial layers faster than the
    // paper's HLS artifact, so the upper bound is generous — see
    // EXPERIMENTS.md §Calibration)
    let tconv_speedup_acc = cpu1.tconv_s / acc1.tconv_s;
    assert!(tconv_speedup_acc > 1.5 && tconv_speedup_acc < 12.0, "tconv speedup {tconv_speedup_acc}");
    let overall_acc = cpu1.total_s() / acc1.total_s();
    assert!(overall_acc > 1.3 && overall_acc < 9.0, "overall speedup {overall_acc}");
    let cpu2_speedup = cpu1.total_s() / cpu2.total_s();
    assert!(cpu2_speedup > 1.3 && cpu2_speedup < 2.0, "2T speedup {cpu2_speedup}");
    let energy_red = cpu1.energy_j / acc1.energy_j;
    assert!(energy_red > 1.1 && energy_red < 8.0, "energy reduction {energy_red}");
    // ACC configs should be close regardless of CPU threads (TCONV moves)
    assert!((acc1.tconv_s - acc2.tconv_s).abs() / acc1.tconv_s < 1e-9);
}

/// pix2pix (TCONV-heavy U-Net): TCONV share dominates like in the paper
/// (2737 of 5238 ms on CPU 1T) and accelerating it pays off end-to-end.
#[test]
fn pix2pix_table4_shape() {
    let g = zoo::pix2pix(128, 32, 0);
    let mut rng = Pcg32::new(32);
    let input = Tensor::<i8>::random(&g.input_shape, &mut rng);
    let exec = Executor::new(Delegate::new(AccelConfig::default(), 2, true));
    let run = exec.run(&g, &input);
    let cfg = AccelConfig::default();
    let cpu1 = run.modeled(RunConfig::Cpu { threads: 1 }, &cfg);
    let acc1 = run.modeled(RunConfig::AccPlusCpu { threads: 1 }, &cfg);
    // TCONV is a large share of CPU-only time
    let share = cpu1.tconv_s / cpu1.total_s();
    assert!(share > 0.3, "tconv share {share}");
    // paper: TCONV 3.0x, overall 1.6x on 1T
    let tconv_speedup = cpu1.tconv_s / acc1.tconv_s;
    let overall = cpu1.total_s() / acc1.total_s();
    assert!(tconv_speedup > 1.5, "tconv speedup {tconv_speedup}");
    assert!(overall > 1.2 && overall < tconv_speedup, "overall {overall}");
}

/// The executor's record stream must expose exactly the graph's TCONV
/// layers with accelerator reports attached when delegated.
#[test]
fn records_have_reports_only_when_accelerated() {
    let g = zoo::dcgan_tf(0);
    let mut rng = Pcg32::new(33);
    let input = Tensor::<i8>::random(&g.input_shape, &mut rng);
    let acc_run = Executor::new(Delegate::new(AccelConfig::default(), 2, true)).run(&g, &input);
    let cpu_run = Executor::new(Delegate::new(AccelConfig::default(), 2, false)).run(&g, &input);
    let acc_reports = acc_run
        .records
        .iter()
        .filter(|r| matches!(&r.work, Work::Tconv { report: Some(_), .. }))
        .count();
    let cpu_reports = cpu_run
        .records
        .iter()
        .filter(|r| matches!(&r.work, Work::Tconv { report: Some(_), .. }))
        .count();
    assert_eq!(acc_reports, 3);
    assert_eq!(cpu_reports, 0);
}

/// Determinism: same graph seed + input seed => identical images.
#[test]
fn end_to_end_determinism() {
    let g1 = zoo::dcgan_tf(5);
    let g2 = zoo::dcgan_tf(5);
    let (a1, _) = run_both(&g1, 77);
    let (a2, _) = run_both(&g2, 77);
    assert_eq!(a1, a2);
}

#[test]
fn style_transfer_accelerated_equals_cpu_only() {
    let g = zoo::style_transfer(16, 8, 0);
    let (a, c) = run_both(&g, 21);
    assert_eq!(a, c);
}
