//! Weight-reuse layer batching: differential guarantees for the batched
//! execution path introduced with shard-persistent accelerators.
//!
//! * Property: `Executor::run_batch` over any shuffled batch of inputs is
//!   byte-identical to `Executor::run` per input — grouping and
//!   submission order can never change numerics.
//! * Server level: shuffled multi-graph submission produces exactly the
//!   per-request reference outputs, and same-layer batches amortize
//!   weight loads (hit rate > 0, fewer loads than the per-request
//!   equivalent).
//! * Resident-weight skip: consecutive same-layer streams on one
//!   persistent accelerator strictly drop cycle counts.
//! * Cross-batch routing: the weight-aware placement scorer steers
//!   consecutive same-layer batches onto the shard that still holds the
//!   filters, so the resident skip fires *across* batches and total
//!   weight loads land strictly below the route-blind baseline.

use mm2im::accel::isa::OutMode;
use mm2im::accel::{AccelConfig, Accelerator};
use mm2im::coordinator::{PlacementPolicy, Request, Server};
use mm2im::driver::instructions::build_layer_stream;
use mm2im::driver::Delegate;
use mm2im::model::executor::Executor;
use mm2im::model::zoo;
use mm2im::tconv::TconvProblem;
use mm2im::tensor::Tensor;
use mm2im::util::prop::check;
use mm2im::util::rng::Pcg32;
use std::sync::Arc;

/// Grouped (batched) graph execution equals per-request execution for
/// random graphs, random batch sizes, and shuffled input order.
#[test]
fn prop_grouped_execution_equals_per_request_under_shuffle() {
    check("grouped-eq-per-request", 6, |g| {
        // A small graph from the zoo, varied by seed; the heavier DCGAN
        // generator appears in ~1/3 of cases to bound debug-mode runtime.
        let graph = match g.int(0, 2) {
            2 => zoo::dcgan_tf(g.int(0, 3) as u64),
            _ => zoo::pix2pix(8, 2, g.int(0, 3) as u64),
        };
        let n = g.int(1, 3);
        let mut inputs: Vec<Tensor<i8>> = (0..n)
            .map(|k| {
                let mut rng = Pcg32::new(g.case_seed ^ (k as u64 + 1));
                Tensor::<i8>::random(&graph.input_shape, &mut rng)
            })
            .collect();
        // Shuffle the batch (Fisher-Yates on the generator's entropy):
        // grouping must be order-insensitive.
        for i in (1..inputs.len()).rev() {
            let j = g.int(0, i);
            inputs.swap(i, j);
        }

        let exec = Executor::new(Delegate::new(AccelConfig::default(), 1, true));
        let batch = exec.run_batch(&graph, &inputs).expect("fault-free batch");
        assert_eq!(batch.outputs.len(), n);
        for (k, input) in inputs.iter().enumerate() {
            let single = exec.run(&graph, input);
            assert_eq!(
                batch.outputs[k].data(),
                single.output.data(),
                "graph {} request {k} of {n}",
                graph.name
            );
        }
    });
}

/// Shuffled submission across two graphs: the scheduler regroups by
/// graph, outputs stay byte-identical to the per-request reference, and
/// batching measurably amortizes weight loads.
#[test]
fn shuffled_multi_graph_submission_is_correct_and_amortizes() {
    let g0 = Arc::new(zoo::pix2pix(8, 2, 0));
    let g1 = Arc::new(zoo::dcgan_tf(1));
    let mut server = Server::builder()
        .graphs([g0.clone(), g1.clone()])
        .shards(1)
        .workers_per_shard(1)
        .queue_capacity(32)
        .max_batch(4)
        .start()
        .expect("valid config");

    // Interleave deterministically-shuffled traffic for both graphs
    // while paused, so the whole pattern is queued before grouping runs.
    server.pause();
    let pattern = [0usize, 1, 0, 0, 1, 0, 1, 0, 0, 0, 1, 0];
    for (seed, &graph) in pattern.iter().enumerate() {
        server.try_submit(Request::seed(seed as u64).graph(graph)).expect("capacity sized");
    }
    server.resume();
    let (responses, stats) = server.finish();
    assert_eq!(responses.len(), pattern.len());

    let reference = Executor::new(Delegate::new(AccelConfig::default(), 1, true));
    for r in &responses {
        let graph = if r.graph == 0 { &g0 } else { &g1 };
        let mut rng = Pcg32::new(r.seed().expect("seeded request"));
        let input = Tensor::<i8>::random(&graph.input_shape, &mut rng);
        let want = reference.run(graph, &input);
        assert_eq!(r.output_tensor().data(), want.output.data(), "id {} graph {}", r.id, r.graph);
    }

    // 8 g0-requests + 4 g1-requests at max_batch 4, all queued up front:
    // batches of width > 1 must have formed, so weight loads amortize.
    assert!(stats.mean_batch_size > 1.0, "mean batch {}", stats.mean_batch_size);
    assert!(stats.weight_loads < stats.weight_loads_equiv);
    assert!(stats.weight_load_hit_rate() > 0.0);
}

/// Cross-batch weight reuse via the placement scorer: two consecutive
/// same-layer batches routed by the modeled-latency scorer land on the
/// same shard, so the second batch's weight load is elided — while the
/// route-blind round-robin baseline pays a fresh load per shard. Total
/// `weight_loads` under the scorer must come in strictly below.
#[test]
fn scorer_routed_consecutive_batches_skip_weight_loads_vs_round_robin() {
    // One TCONV, one tile (Oc = 8 = X): what stays resident after a
    // batch is exactly what the next batch loads first.
    let p = TconvProblem::new(5, 5, 16, 3, 8, 2);
    let graph = Arc::new(zoo::single_tconv("single_tconv", p, 88));

    // Two identical shards, one worker each; 4 queued requests at
    // max_batch 2 form exactly two consecutive same-layer batches.
    // tolerance 0 makes the steer deterministic: batch 1 ties everywhere
    // and lands on shard 0; batch 2 sees shard 0's resident bonus as the
    // strict minimum and follows it there.
    let run = |placement: PlacementPolicy| {
        let mut server = Server::builder()
            .graph(graph.clone())
            .workers_per_shard(1)
            .queue_capacity(8)
            .max_batch(2)
            .shard_fleet(vec![AccelConfig::default(), AccelConfig::default()])
            .placement(placement)
            .start()
            .expect("valid config");
        server.pause();
        for seed in 0..4 {
            server.try_submit(Request::seed(seed)).expect("capacity sized");
        }
        server.resume();
        let (responses, stats) = server.finish();
        assert_eq!(responses.len(), 4);
        (responses, stats)
    };

    let (scored_responses, scored) = run(PlacementPolicy::Modeled { tolerance: 0.0 });
    let (rr_responses, rr) = run(PlacementPolicy::RoundRobin);

    assert_eq!(scored.batches, 2, "4 requests at max_batch 2");
    assert_eq!(rr.batches, 2);
    // Routing must never change bytes.
    for (a, b) in scored_responses.iter().zip(&rr_responses) {
        assert_eq!(a.output_tensor().data(), b.output_tensor().data(), "id {}", a.id);
    }

    // The scorer kept both batches on one shard: the second batch's
    // stream reports its weight load skipped.
    assert!(
        scored.cross_batch_resident_hits >= 1,
        "second scored batch must hit the resident filter set: {scored:?}"
    );
    assert!(scored.weight_loads_skipped > 0);
    assert_eq!(scored.weight_loads, 1, "one transfer serves both batches");
    let routed_to: Vec<usize> = scored.placements.iter().map(|d| d.shard).collect();
    assert_eq!(routed_to[0], routed_to[1], "consecutive batches share a shard");
    assert!(scored.placements[1].resident_hit_predicted, "the steer was deliberate");

    // Route-blind baseline alternates shards: every batch pays a load.
    assert_eq!(rr.weight_loads, 2);
    assert_eq!(rr.cross_batch_resident_hits, 0);
    assert!(
        scored.weight_loads < rr.weight_loads,
        "scorer must strictly reduce weight loads: {} vs {}",
        scored.weight_loads,
        rr.weight_loads
    );
}

/// Resident-weight skip on a persistent accelerator: replaying the same
/// single-tile layer strictly drops the cycle count, and the skipped
/// transfer is visible in the report.
#[test]
fn persistent_accelerator_skips_resident_weight_loads() {
    let cfg = AccelConfig::default();
    let p = TconvProblem::new(5, 5, 16, 3, 8, 2); // Oc = 8 = X: one tile
    let mut rng = Pcg32::new(77);
    let w = Tensor::<i8>::random(&[p.oc, p.ks, p.ks, p.ic], &mut rng);
    let bias = vec![0i32; p.oc];
    let mut acc = Accelerator::new(cfg.clone());

    let mut first_cycles = None;
    for round in 0..3u64 {
        let mut xrng = Pcg32::new(100 + round);
        let x = Tensor::<i8>::random(&[p.ih, p.iw, p.ic], &mut xrng);
        let stream = build_layer_stream(&p, &x, &w, &bias, None, &cfg, OutMode::Raw32);
        let got = acc.run_stream(&stream).unwrap();
        match first_cycles {
            None => {
                assert_eq!(got.report.weight_loads, 1);
                assert_eq!(got.report.weight_loads_skipped, 0);
                first_cycles = Some(got.report.total_cycles);
            }
            Some(first) => {
                assert_eq!(got.report.weight_loads, 0, "round {round}");
                assert_eq!(got.report.weight_loads_skipped, 1, "round {round}");
                assert!(
                    got.report.total_cycles < first,
                    "round {round}: {} vs first {first}",
                    got.report.total_cycles
                );
            }
        }
        // Numerics are unaffected by the skip.
        let want = mm2im::tconv::reference::direct_i32(&p, &x, &w, Some(&bias));
        assert_eq!(got.raw.data(), want.data(), "round {round}");
    }
}
