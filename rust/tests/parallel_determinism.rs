//! Determinism net for the fused engine's tile-parallel execution
//! (`AccelConfig::host_threads`): a stream executed with N host lanes
//! must be **byte-identical** — raw i32 accumulators, quantized int8
//! output, *and* the full `CycleReport` — to the same stream executed
//! serially, across the 32-config sweep sample, batched streams, and a
//! shuffled-tile-order property test. The parallel split hands each
//! lane disjoint PM accumulators and computes cycle charges in closed
//! form on the issuing thread, so any scheduling-dependent behaviour
//! whatsoever shows up here as a mismatch.

use mm2im::accel::isa::{Instr, OutMode};
use mm2im::accel::{AccelConfig, Accelerator};
use mm2im::bench::workloads::sweep261;
use mm2im::driver::instructions::compile_layer;
use mm2im::tconv::TconvProblem;
use mm2im::tensor::quant::{PerChannel, QuantParams};
use mm2im::tensor::Tensor;
use mm2im::util::prop;
use mm2im::util::rng::Pcg32;

/// Same deterministic sampling as `engine_differential.rs`: every axis
/// of the 261-problem grid, debug-mode-sized.
const MAC_BUDGET: u64 = 4_000_000;
const SAMPLE_TARGET: usize = 32;

fn sample() -> Vec<TconvProblem> {
    let eligible: Vec<TconvProblem> = sweep261()
        .into_iter()
        .map(|e| e.problem)
        .filter(|p| p.macs() <= MAC_BUDGET)
        .collect();
    let step = (eligible.len() / SAMPLE_TARGET).max(1);
    let picked: Vec<TconvProblem> =
        eligible.into_iter().step_by(step).take(SAMPLE_TARGET).collect();
    assert!(picked.len() >= 30, "determinism sample must cover >= 30 configs");
    picked
}

fn case(p: &TconvProblem, seed: u64) -> (Tensor<i8>, Tensor<i8>, Vec<i32>) {
    let mut rng = Pcg32::new(seed);
    let x = Tensor::<i8>::random(&[p.ih, p.iw, p.ic], &mut rng);
    let w = Tensor::<i8>::random(&[p.oc, p.ks, p.ks, p.ic], &mut rng);
    let bias: Vec<i32> = (0..p.oc).map(|i| (i as i32 % 11) * 5 - 20).collect();
    (x, w, bias)
}

/// `host_threads = 4` with the size gate forced open, so even the
/// debug-sized sweep problems take the parallel path.
fn wide(cfg: &AccelConfig) -> AccelConfig {
    AccelConfig { host_threads: 4, host_parallel_min_macs: 0, ..cfg.clone() }
}

/// threads=4 == threads=1 across the sweep sample: byte-identical raw +
/// quant outputs and an *identical* CycleReport, in both output modes.
#[test]
fn sweep_sample_threads_and_serial_bit_identical() {
    let cfg = AccelConfig::default();
    assert_eq!(cfg.resolved_host_threads(), 1, "serial must be the default");
    for (i, p) in sample().iter().enumerate() {
        let (x, w, bias) = case(p, 9000 + i as u64);
        let out_q = QuantParams { scale: 0.04, zero_point: 2 };
        let requant = PerChannel::new(0.02, &vec![0.01; p.oc], out_q);
        for (out_mode, rq) in [(OutMode::Raw32, None), (OutMode::Int8, Some(&requant))] {
            let plan = compile_layer(p, &w, &bias, rq, &cfg, out_mode);
            let stream = plan.instantiate(&x);
            let serial = Accelerator::new(cfg.clone())
                .execute(&stream)
                .unwrap_or_else(|e| panic!("{p} serial: {e}"));
            let par = Accelerator::new(wide(&cfg))
                .execute(&stream)
                .unwrap_or_else(|e| panic!("{p} threads=4: {e}"));
            assert_eq!(par.raw.data(), serial.raw.data(), "{p} {out_mode:?}: raw diverges");
            assert_eq!(par.quant.data(), serial.quant.data(), "{p} {out_mode:?}: quant diverges");
            assert_eq!(par.report, serial.report, "{p} {out_mode:?}: CycleReport diverges");
        }
    }
}

/// Batched streams (`run_batch`, SelectOutput splicing) under threads=4:
/// every slot byte-identical to the serial run, identical reports. Also
/// covers `host_threads = 0` (auto-detect) on one case.
#[test]
fn batched_streams_threads_and_serial_bit_identical() {
    let cfg = AccelConfig::default();
    for (p, seed) in [
        (TconvProblem::new(5, 5, 24, 3, 20, 2), 131u64), // three tiles over X=8
        (TconvProblem::new(4, 4, 64, 5, 6, 1), 132),     // one tile, deeper Ic
    ] {
        let (_, w, bias) = case(&p, seed);
        let mut rng = Pcg32::new(seed + 500);
        let xs: Vec<Tensor<i8>> = (0..3)
            .map(|_| Tensor::<i8>::random(&[p.ih, p.iw, p.ic], &mut rng))
            .collect();
        let refs: Vec<&Tensor<i8>> = xs.iter().collect();
        let plan = compile_layer(&p, &w, &bias, None, &cfg, OutMode::Raw32);
        let stream = plan.instantiate_batch(&refs);
        let serial = Accelerator::new(cfg.clone()).run_batch(&stream).unwrap();
        let par = Accelerator::new(wide(&cfg)).run_batch(&stream).unwrap();
        let auto = Accelerator::new(AccelConfig {
            host_threads: 0,
            host_parallel_min_macs: 0,
            ..cfg.clone()
        })
        .run_batch(&stream)
        .unwrap();
        assert_eq!(par.outputs.len(), serial.outputs.len());
        for (k, (f, s)) in par.outputs.iter().zip(serial.outputs.iter()).enumerate() {
            assert_eq!(f.0.data(), s.0.data(), "{p} slot {k}: raw diverges");
            assert_eq!(f.1.data(), s.1.data(), "{p} slot {k}: quant diverges");
        }
        for (k, (f, s)) in auto.outputs.iter().zip(serial.outputs.iter()).enumerate() {
            assert_eq!(f.0.data(), s.0.data(), "{p} slot {k}: auto-threads raw diverges");
        }
        assert_eq!(par.report, serial.report, "{p}: batched report diverges");
        assert_eq!(auto.report, serial.report, "{p}: auto-threads report diverges");
    }
}

/// Default-threshold behaviour: with `host_parallel_min_macs` left at
/// its default, small passes stay serial and big-`Ic` passes fan out —
/// both gate decisions must leave outputs and reports untouched.
#[test]
fn default_threshold_both_sides_identical() {
    let cfg = AccelConfig::default();
    for (p, seed) in [
        (TconvProblem::new(3, 3, 8, 3, 6, 2), 141u64), // tiny: below the gate
        // Stride 1 keeps every candidate tap alive: 40 taps * 8 PMs *
        // Ic=1024 = 327K MACs/pass, well past the default gate.
        (TconvProblem::new(2, 8, 1024, 5, 8, 1), 142),
    ] {
        let (x, w, bias) = case(&p, seed);
        let plan = compile_layer(&p, &w, &bias, None, &cfg, OutMode::Raw32);
        let stream = plan.instantiate(&x);
        let serial = Accelerator::new(cfg.clone()).execute(&stream).unwrap();
        let par = Accelerator::new(AccelConfig { host_threads: 4, ..cfg.clone() })
            .execute(&stream)
            .unwrap();
        assert_eq!(par.raw.data(), serial.raw.data(), "{p}: raw diverges");
        assert_eq!(par.report, serial.report, "{p}: report diverges");
    }
}

/// Shuffled-tile-order property: a multi-tile stream's per-tile
/// segments (each `Configure`-led: prologue + row schedule) can be
/// executed in any order — tiles own disjoint output-channel ranges,
/// `Configure` resets the row buffer, and every tile of an
/// X-divisible layer has the same instruction shape — so outputs are
/// byte-identical and, with distinct per-tile weight sets, the
/// `CycleReport` is too. Run under threads=4 against the unshuffled
/// serial stream, so the property also stresses pool reuse across
/// differently-ordered segments.
#[test]
fn shuffled_tile_order_threads_and_serial_bit_identical() {
    prop::check("shuffled-tile-order-parallel", 12, |g| {
        let cfg = AccelConfig::default();
        let tiles = g.int(2, 4);
        let p = TconvProblem::new(
            g.int(2, 4),
            g.int(2, 5),
            8 * g.int(1, 4),
            g.int(2, 4),
            cfg.x_pms * tiles, // every tile full: equal instruction shapes
            g.int(1, 3),
        );
        let (x, w, bias) = case(&p, 150 + g.case_seed % 1000);
        let plan = compile_layer(&p, &w, &bias, None, &cfg, OutMode::Raw32);
        assert_eq!(plan.tiles.len(), tiles, "{p}: tile count");

        let serial = Accelerator::new(cfg.clone()).execute(&plan.instantiate(&x)).unwrap();

        // Split the stream into Configure-led tile segments and
        // Fisher-Yates shuffle them.
        let mut segments: Vec<Vec<Instr>> = Vec::new();
        for ins in plan.instantiate(&x) {
            if matches!(ins, Instr::Configure(_)) {
                segments.push(Vec::new());
            }
            segments.last_mut().expect("stream starts with Configure").push(ins);
        }
        assert_eq!(segments.len(), tiles);
        for i in (1..segments.len()).rev() {
            let j = g.int(0, i);
            segments.swap(i, j);
        }
        let shuffled: Vec<Instr> = segments.into_iter().flatten().collect();

        let par = Accelerator::new(wide(&cfg)).execute(&shuffled).unwrap();
        assert_eq!(par.raw.data(), serial.raw.data(), "{p}: shuffled raw diverges");
        assert_eq!(par.quant.data(), serial.quant.data(), "{p}: shuffled quant diverges");
        assert_eq!(par.report, serial.report, "{p}: shuffled report diverges");
    });
}
