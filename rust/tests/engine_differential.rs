//! Differential net for the fused GEMM+col2IM execution engine
//! (`AccelConfig::exec_engine`): the fused path must be **bit-identical**
//! to the legacy per-tap scalar path — raw accumulators, requantized
//! int8, *and* the full `CycleReport` (the engine derives its charges in
//! closed form; any census drift shows up as a report mismatch) — across
//! the 32-config sweep sample, both ablation configurations, and batched
//! streams. A property test pins down the associativity argument the
//! GEMM restructure rests on: tap order never changes i32 accumulators.

use mm2im::accel::isa::OutMode;
use mm2im::accel::pm::ProcessingModule;
use mm2im::accel::{Accelerator, AccelConfig, ExecEngine};
use mm2im::bench::workloads::sweep261;
use mm2im::driver::instructions::compile_layer;
use mm2im::tconv::TconvProblem;
use mm2im::tensor::quant::{PerChannel, QuantParams};
use mm2im::tensor::Tensor;
use mm2im::util::prop;
use mm2im::util::rng::Pcg32;

/// Same deterministic sampling as `differential_sweep.rs`: every axis of
/// the 261-problem grid, debug-mode-sized.
const MAC_BUDGET: u64 = 4_000_000;
const SAMPLE_TARGET: usize = 32;

fn sample() -> Vec<TconvProblem> {
    let eligible: Vec<TconvProblem> = sweep261()
        .into_iter()
        .map(|e| e.problem)
        .filter(|p| p.macs() <= MAC_BUDGET)
        .collect();
    let step = (eligible.len() / SAMPLE_TARGET).max(1);
    let picked: Vec<TconvProblem> =
        eligible.into_iter().step_by(step).take(SAMPLE_TARGET).collect();
    assert!(picked.len() >= 30, "differential sample must cover >= 30 configs");
    picked
}

fn case(p: &TconvProblem, seed: u64) -> (Tensor<i8>, Tensor<i8>, Vec<i32>) {
    let mut rng = Pcg32::new(seed);
    let x = Tensor::<i8>::random(&[p.ih, p.iw, p.ic], &mut rng);
    let w = Tensor::<i8>::random(&[p.oc, p.ks, p.ks, p.ic], &mut rng);
    let bias: Vec<i32> = (0..p.oc).map(|i| (i as i32 % 13) * 7 - 40).collect();
    (x, w, bias)
}

fn scalar(cfg: &AccelConfig) -> AccelConfig {
    AccelConfig { exec_engine: ExecEngine::Scalar, ..cfg.clone() }
}

/// Fused == scalar across the sweep sample: byte-identical raw + quant
/// outputs and an *identical* CycleReport, in both output modes.
#[test]
fn sweep_sample_fused_and_scalar_bit_identical() {
    let cfg = AccelConfig::default();
    assert_eq!(cfg.exec_engine, ExecEngine::Fused, "fused engine must be the default");
    for (i, p) in sample().iter().enumerate() {
        let (x, w, bias) = case(p, 5000 + i as u64);
        // Raw32 and a real per-channel requant path both go through the
        // engine's scatter + the PPU.
        let out_q = QuantParams { scale: 0.05, zero_point: -3 };
        let requant = PerChannel::new(0.02, &vec![0.01; p.oc], out_q);
        for (out_mode, rq) in [(OutMode::Raw32, None), (OutMode::Int8, Some(&requant))] {
            let plan = compile_layer(p, &w, &bias, rq, &cfg, out_mode);
            let stream = plan.instantiate(&x);
            let fused = Accelerator::new(cfg.clone())
                .execute(&stream)
                .unwrap_or_else(|e| panic!("{p} fused: {e}"));
            let scal = Accelerator::new(scalar(&cfg))
                .execute(&stream)
                .unwrap_or_else(|e| panic!("{p} scalar: {e}"));
            assert_eq!(fused.raw.data(), scal.raw.data(), "{p} {out_mode:?}: raw diverges");
            assert_eq!(fused.quant.data(), scal.quant.data(), "{p} {out_mode:?}: quant diverges");
            assert_eq!(fused.report, scal.report, "{p} {out_mode:?}: CycleReport diverges");
        }
    }
}

/// Both ablation configurations (mapper off → omap over AXI; cmap skip
/// off → wasted-MAC charging) keep the two engines identical, reports
/// included — the analytic wasted/distinct-pixel censuses must match the
/// scalar tallies exactly.
#[test]
fn ablation_configs_fused_and_scalar_bit_identical() {
    for mutate in [
        (|c: &mut AccelConfig| c.mapper_enabled = false) as fn(&mut AccelConfig),
        |c: &mut AccelConfig| c.cmap_skip_enabled = false,
        |c: &mut AccelConfig| c.cu_reload_input_per_tap = false,
    ] {
        let mut cfg = AccelConfig::default();
        mutate(&mut cfg);
        for (p, seed) in [
            (TconvProblem::new(6, 6, 16, 5, 8, 2), 61u64),
            (TconvProblem::new(7, 5, 32, 3, 11, 1), 62),
            (TconvProblem::new(3, 3, 8, 2, 4, 3), 63), // Ks < S
        ] {
            let (x, w, bias) = case(&p, seed);
            let plan = compile_layer(&p, &w, &bias, None, &cfg, OutMode::Raw32);
            let stream = plan.instantiate(&x);
            let fused = Accelerator::new(cfg.clone()).execute(&stream).unwrap();
            let scal = Accelerator::new(scalar(&cfg)).execute(&stream).unwrap();
            assert_eq!(fused.raw.data(), scal.raw.data(), "{p}: ablation raw diverges");
            assert_eq!(fused.report, scal.report, "{p}: ablation report diverges");
        }
    }
}

/// Batched streams (`run_batch`, SelectOutput splicing) through the
/// fused engine: every slot byte-identical to the scalar path, one
/// shared timeline, identical reports.
#[test]
fn batched_streams_fused_and_scalar_bit_identical() {
    let cfg = AccelConfig::default();
    for (p, seed) in [
        (TconvProblem::new(5, 5, 8, 3, 12, 2), 71u64), // two tiles over X=8
        (TconvProblem::new(4, 4, 16, 5, 6, 1), 72),    // one tile
    ] {
        let (_, w, bias) = case(&p, seed);
        let mut rng = Pcg32::new(seed + 100);
        let xs: Vec<Tensor<i8>> = (0..3)
            .map(|_| Tensor::<i8>::random(&[p.ih, p.iw, p.ic], &mut rng))
            .collect();
        let refs: Vec<&Tensor<i8>> = xs.iter().collect();
        let plan = compile_layer(&p, &w, &bias, None, &cfg, OutMode::Raw32);
        let stream = plan.instantiate_batch(&refs);
        let fused = Accelerator::new(cfg.clone()).run_batch(&stream).unwrap();
        let scal = Accelerator::new(scalar(&cfg)).run_batch(&stream).unwrap();
        assert_eq!(fused.outputs.len(), scal.outputs.len());
        for (k, (f, s)) in fused.outputs.iter().zip(scal.outputs.iter()).enumerate() {
            assert_eq!(f.0.data(), s.0.data(), "{p} slot {k}: raw diverges");
            assert_eq!(f.1.data(), s.1.data(), "{p} slot {k}: quant diverges");
        }
        assert_eq!(fused.report, scal.report, "{p}: batched report diverges");
    }
}

/// Persistent-instance parity: the resident-weight skip (which also
/// skips the engine's repack) must leave both engines identical across
/// consecutive streams.
#[test]
fn resident_skip_keeps_engines_identical() {
    let cfg = AccelConfig::default();
    let p = TconvProblem::new(4, 4, 8, 3, 6, 2); // one tile: skip fires
    let (x, w, bias) = case(&p, 81);
    let plan = compile_layer(&p, &w, &bias, None, &cfg, OutMode::Raw32);
    let stream = plan.instantiate(&x);
    let mut fused = Accelerator::new(cfg.clone());
    let mut scal = Accelerator::new(scalar(&cfg));
    for round in 0..3 {
        let f = fused.run_stream(&stream).unwrap();
        let s = scal.run_stream(&stream).unwrap();
        assert_eq!(f.raw.data(), s.raw.data(), "round {round}");
        assert_eq!(f.report, s.report, "round {round}");
        if round > 0 {
            assert_eq!(f.report.weight_loads_skipped, 1, "round {round}: skip must fire");
        }
    }
}

/// The associativity property the GEMM restructure rests on: shuffling
/// the order taps are applied in never changes the i32 accumulators
/// (integer addition is associative and commutative; the engine merely
/// regroups the same sums).
#[test]
fn shuffled_tap_order_never_changes_accumulators() {
    prop::check("shuffled-tap-order", 40, |g| {
        let ih = g.int(1, 4);
        let iw = g.int(1, 6);
        let ic = g.int(1, 48);
        let ks = g.int(1, 5);
        let stride = g.int(1, 3);
        let p = TconvProblem::new(ih, iw, ic, ks, 1, stride);
        let x = Tensor::<i8>::from_vec(&[1, p.iw, p.ic], g.vec_i8(p.iw * p.ic));
        let weights = g.vec_i8(p.ks * p.ks * p.ic);
        let payload = mm2im::accel::isa::FilterPayload {
            weights: weights.into(),
            bias: g.int(0, 2000) as i32 - 1000,
            qmult_m: 1 << 30,
            qmult_shift: 1,
            zp_out: 0,
        };
        let cfg = AccelConfig::default();
        let mapper = mm2im::accel::mapper::Mapper::configure(&p);
        let taps = mapper.row_maps(0, 0, &cfg).taps;
        let kh = g.int(0, p.ks - 1);
        let candidates = p.mapper.candidate_taps(p.iw, p.ks, taps.len());

        // Reference order.
        let mut pm = ProcessingModule::new();
        pm.load_filter(&payload, p.ks, p.ic);
        pm.begin_row(p.ow());
        pm.compute_pass_taps(x.data(), &taps, kh, candidates, &cfg);
        let (want, _, _) = pm.finish_row(&cfg);

        // Fisher–Yates shuffle of the tap list.
        let mut shuffled = taps.clone();
        for i in (1..shuffled.len()).rev() {
            let j = g.int(0, i);
            shuffled.swap(i, j);
        }
        let mut pm2 = ProcessingModule::new();
        pm2.load_filter(&payload, p.ks, p.ic);
        pm2.begin_row(p.ow());
        pm2.compute_pass_taps(x.data(), &shuffled, kh, candidates, &cfg);
        let (got, _, _) = pm2.finish_row(&cfg);
        assert_eq!(got, want, "tap order changed accumulators ({p}, kh={kh})");
    });
}
