//! Telemetry subsystem acceptance: the tree is the serving stack's
//! single source of truth, and everything else is a projection of it.
//!
//! * **Projection fidelity** — across a chaos run (the pinned `mixed`
//!   fault spec from `tests/chaos.rs`), the [`ServeStats`] returned by
//!   `finish` equals [`ServeStats::from_snapshot`] over a snapshot taken
//!   *after* finish, bit-for-bit on every field including the `f64`s.
//!   Nothing mutates the tree once the workers join, so the two
//!   projections must be byte-identical.
//! * **Diff monotonicity** — counters never decrease between an early
//!   [`Server::inspect`] and the final snapshot; [`Snapshot::diff`]
//!   pins `delta() >= 0` for every shared counter path.
//! * **Typed query misses** — wrong paths and wrong kinds come back as
//!   [`QueryError::Missing`] / [`QueryError::Kind`] values, never
//!   panics, and their `Display` names the path.
//! * **JSON stability** — `to_json` round-trips through
//!   [`Snapshot::from_json`] to the identical string, and the
//!   round-tripped snapshot projects the identical `ServeStats` (the
//!   `serve --stats-json` → `repro stats` offline path).

use mm2im::accel::{FaultPlan, FaultSpec};
use mm2im::coordinator::{Priority, Request, ServeStats, Server};
use mm2im::model::zoo;
use mm2im::telemetry::{triage, QueryError, Snapshot, Tree};
use std::sync::Arc;
use std::time::Duration;

/// The pinned chaos spec from `tests/chaos.rs`'s `mixed` plan (also the
/// CI `MM2IM_FAULT_SPEC` matrix leg): transients, corrupt transfers and
/// stalls all active, seeded so every run replays identically.
fn mixed_spec() -> FaultSpec {
    FaultSpec::new(14).transient(0.1).corrupt(0.1).stall(0.2, 1)
}

/// A chaos serve run exercising every ledger term: served traffic in
/// two classes, one cancelled ticket, one lapsed deadline, plus the
/// mixed fault plan driving retries/failures. Returns the telemetry
/// handle (which outlives `finish`) and the stats `finish` projected.
fn chaos_run() -> (Arc<Tree>, ServeStats) {
    let graph = Arc::new(zoo::pix2pix(8, 2, 0));
    let mut server = Server::builder()
        .graph(graph)
        .shards(2)
        .workers_per_shard(1)
        .queue_capacity(32)
        .max_batch(2)
        .fault_plan(FaultPlan::new(mixed_spec()))
        .retry_budget(2)
        .quarantine_after(2)
        .start()
        .expect("valid config");
    server.pause();
    for seed in 0..10u64 {
        let class = if seed % 3 == 0 { Priority::High } else { Priority::Normal };
        server.try_submit(Request::seed(seed).priority(class)).expect("capacity sized");
    }
    // One ticket cancelled while queued, one deadline that can never be
    // met: the cancelled / deadline_expired ledger terms go nonzero.
    let doomed = server.try_submit(Request::seed(100).priority(Priority::Low)).expect("capacity");
    assert!(doomed.cancel(), "a paused queue cannot have served the ticket yet");
    server
        .try_submit(Request::seed(101).deadline(Duration::ZERO))
        .expect("capacity sized");
    server.resume();
    let telem = server.telemetry();
    let (responses, stats) = server.finish();
    assert_eq!(responses.len(), 12, "every submission resolves exactly once");
    (telem, stats)
}

/// Bit-for-bit `ServeStats` equality: `u64`/`Vec` fields by value,
/// every `f64` by its bit pattern (`to_bits`), so a projection that
/// recomputes a derived quantity differently cannot sneak through.
fn assert_stats_identical(a: &ServeStats, b: &ServeStats) {
    assert_eq!(a.requests, b.requests, "requests");
    assert_eq!(a.submitted, b.submitted, "submitted");
    assert_eq!(a.cancelled, b.cancelled, "cancelled");
    assert_eq!(a.deadline_expired, b.deadline_expired, "deadline_expired");
    assert_eq!(a.requests_failed, b.requests_failed, "requests_failed");
    assert_eq!(a.exec_failures, b.exec_failures, "exec_failures");
    assert_eq!(a.retries, b.retries, "retries");
    assert_eq!(a.probes, b.probes, "probes");
    assert_eq!(a.probe_recoveries, b.probe_recoveries, "probe_recoveries");
    assert_eq!(a.shards_quarantined, b.shards_quarantined, "shards_quarantined");
    assert_eq!(a.shard_health, b.shard_health, "shard_health");
    assert_eq!(a.worker_failures, b.worker_failures, "worker_failures");
    let bits = |x: f64| x.to_bits();
    assert_eq!(bits(a.wall_total_s), bits(b.wall_total_s), "wall_total_s");
    assert_eq!(bits(a.wall_mean_s), bits(b.wall_mean_s), "wall_mean_s");
    assert_eq!(bits(a.modeled_mean_s), bits(b.modeled_mean_s), "modeled_mean_s");
    assert_eq!(bits(a.throughput_rps), bits(b.throughput_rps), "throughput_rps");
    assert_eq!(bits(a.p50_latency_s), bits(b.p50_latency_s), "p50_latency_s");
    assert_eq!(bits(a.p95_latency_s), bits(b.p95_latency_s), "p95_latency_s");
    assert_eq!(a.cache_hits, b.cache_hits, "cache_hits");
    assert_eq!(a.cache_misses, b.cache_misses, "cache_misses");
    assert_eq!(a.batches, b.batches, "batches");
    assert_eq!(bits(a.mean_batch_size), bits(b.mean_batch_size), "mean_batch_size");
    assert_eq!(a.weight_loads, b.weight_loads, "weight_loads");
    assert_eq!(a.weight_loads_skipped, b.weight_loads_skipped, "weight_loads_skipped");
    assert_eq!(a.weight_loads_equiv, b.weight_loads_equiv, "weight_loads_equiv");
    assert_eq!(a.cross_graph_batches, b.cross_graph_batches, "cross_graph_batches");
    assert_eq!(
        a.cross_batch_resident_hits, b.cross_batch_resident_hits,
        "cross_batch_resident_hits"
    );
    assert_eq!(a.plans_preloaded, b.plans_preloaded, "plans_preloaded");
    assert_eq!(
        a.shard_utilization.iter().map(|&u| bits(u)).collect::<Vec<_>>(),
        b.shard_utilization.iter().map(|&u| bits(u)).collect::<Vec<_>>(),
        "shard_utilization"
    );
    assert_eq!(a.shard_requests, b.shard_requests, "shard_requests");
    assert_eq!(a.shard_config_fps, b.shard_config_fps, "shard_config_fps");
    assert_eq!(a.placements.len(), b.placements.len(), "placements length");
    for (i, (pa, pb)) in a.placements.iter().zip(&b.placements).enumerate() {
        assert_eq!(pa.graph, pb.graph, "placement {i} graph");
        assert_eq!(pa.requests, pb.requests, "placement {i} requests");
        assert_eq!(pa.shard, pb.shard, "placement {i} shard");
        assert_eq!(
            pa.scores_s.iter().map(|&s| bits(s)).collect::<Vec<_>>(),
            pb.scores_s.iter().map(|&s| bits(s)).collect::<Vec<_>>(),
            "placement {i} scores"
        );
        assert_eq!(pa.resident_hit_predicted, pb.resident_hit_predicted, "placement {i} hit");
    }
}

/// The legacy stats struct is exactly the final snapshot's projection —
/// every field, bit-for-bit, under the pinned mixed chaos spec.
#[test]
fn projection_reproduces_finish_stats_bit_for_bit_under_chaos() {
    let (telem, stats) = chaos_run();
    let snap = telem.snapshot();
    let projected = ServeStats::from_snapshot(&snap).expect("server trees always project");
    assert_stats_identical(&stats, &projected);

    // The run actually exercised the ledger: something served, the
    // cancel and the zero deadline resolved, and the built-in triage
    // rules (ledger identity above all) hold on the final snapshot.
    assert!(stats.requests > 0, "chaos run must serve: {stats:?}");
    assert_eq!(stats.cancelled, 1, "{stats:?}");
    assert_eq!(stats.deadline_expired, 1, "{stats:?}");
    let report = triage::evaluate(&triage::default_rules(), &snap);
    assert!(report.healthy(), "final triage must be green:\n{report}");
}

/// Counters only move forward: every counter present in both an early
/// and the final snapshot has a non-negative delta, and the ledger
/// terms all grew to their final values.
#[test]
fn snapshot_diff_is_monotone_over_a_serve_run() {
    let graph = Arc::new(zoo::pix2pix(8, 2, 0));
    let mut server = Server::builder()
        .graph(graph)
        .shards(2)
        .workers_per_shard(1)
        .queue_capacity(16)
        .max_batch(2)
        .no_fault_injection()
        .start()
        .expect("valid config");
    for seed in 0..4u64 {
        server.submit(Request::seed(seed)).expect("seeded requests validate");
    }
    let early = server.inspect();
    for seed in 4..8u64 {
        server.submit(Request::seed(seed)).expect("seeded requests validate");
    }
    let telem = server.telemetry();
    let (responses, stats) = server.finish();
    assert_eq!(responses.len(), 8);
    let last = telem.snapshot();

    let deltas = last.diff(&early);
    assert!(!deltas.is_empty(), "two snapshots of one tree share counter paths");
    for d in &deltas {
        assert!(d.delta() >= 0, "counter {} went backwards: {} -> {}", d.path, d.earlier, d.later);
    }
    let served = deltas.iter().find(|d| d.path == "fleet/served").expect("ledger counter");
    assert_eq!(served.later, stats.requests, "final served reading matches the projection");
    let submitted = deltas.iter().find(|d| d.path == "fleet/submitted").expect("ledger counter");
    assert_eq!(submitted.later, 8);
    assert!(submitted.earlier >= 4, "the early snapshot saw the first burst");
}

/// Bad queries are typed values, not panics: a missing path reports
/// [`QueryError::Missing`], a kind mismatch reports [`QueryError::Kind`]
/// with both kinds named, and `Display` carries the path.
#[test]
fn path_queries_miss_with_typed_errors() {
    let tree = Tree::new();
    tree.counter("fleet/served").add(3);
    tree.text("fleet/shard/0/health").set("healthy");
    let snap = tree.snapshot();

    match snap.counter("fleet/nope") {
        Err(QueryError::Missing(path)) => assert_eq!(path, "fleet/nope"),
        other => panic!("expected Missing, got {other:?}"),
    }
    match snap.gauge("fleet/served") {
        Err(QueryError::Kind { path, want, got }) => {
            assert_eq!(path, "fleet/served");
            assert_eq!((want, got), ("gauge", "counter"));
        }
        other => panic!("expected Kind, got {other:?}"),
    }
    match snap.counter("fleet/shard/0/health") {
        Err(QueryError::Kind { want, got, .. }) => assert_eq!((want, got), ("counter", "text")),
        other => panic!("expected Kind, got {other:?}"),
    }
    let msg = snap.ring("fleet/served").expect_err("wrong kind").to_string();
    assert!(msg.contains("fleet/served"), "Display names the path: {msg}");
    assert_eq!(snap.counter("fleet/served"), Ok(3));
    assert_eq!(snap.text("fleet/shard/0/health").as_deref(), Ok("healthy"));
}

/// The JSON dump is stable: parsing it back yields a snapshot that
/// serializes to the identical string and projects the identical
/// `ServeStats` — the offline `repro stats` contract.
#[test]
fn json_round_trip_is_stable_and_projects_identically() {
    let (telem, stats) = chaos_run();
    let snap = telem.snapshot();
    let json = snap.to_json();

    let reparsed = Snapshot::from_json(&json).expect("own dumps always parse");
    assert_eq!(reparsed.to_json(), json, "round-trip must be byte-stable");
    assert_eq!(reparsed.epoch(), snap.epoch(), "the dump carries the seqlock epoch");

    let projected = ServeStats::from_snapshot(&reparsed).expect("round-tripped trees project");
    assert_stats_identical(&stats, &projected);

    // Triage works offline too — same verdicts on the parsed dump.
    let report = triage::evaluate(&triage::default_rules(), &reparsed);
    assert!(report.healthy(), "offline triage must match live:\n{report}");
}
