//! Differential test net over the §V-B sweep: for a ≥30-config sample of
//! the 261 benchmark problems, the simulated accelerator must be
//! bit-exact with the CPU baseline and the direct reference — and the
//! stream instantiated from a *cached* compiled plan must produce exactly
//! the bytes the freshly-compiled path produces.
//!
//! The sample is deterministic: all configs whose MatMul-view MAC count
//! fits a debug-mode budget, evenly strided. (The every-10th full-range
//! pass, including the largest problems, lives in `integration.rs`.)

use mm2im::accel::isa::OutMode;
use mm2im::accel::{Accelerator, AccelConfig, ExecEngine};
use mm2im::bench::workloads::sweep261;
use mm2im::cpu::baseline;
use mm2im::driver::instructions::{build_layer_stream, compile_layer};
use mm2im::driver::{PlanCache, PlanKey};
use mm2im::tconv::{reference, MapperKind, TconvProblem};
use mm2im::tensor::Tensor;
use mm2im::util::rng::Pcg32;

/// Debug-mode per-problem budget: keeps the 30+ sample fast while still
/// spanning every (Oc, Ks, Ih, Ic, S) axis of the grid.
const MAC_BUDGET: u64 = 4_000_000;
const SAMPLE_TARGET: usize = 32;

fn sample() -> Vec<TconvProblem> {
    let eligible: Vec<TconvProblem> = sweep261()
        .into_iter()
        .map(|e| e.problem)
        .filter(|p| p.macs() <= MAC_BUDGET)
        .collect();
    assert!(
        eligible.len() >= SAMPLE_TARGET,
        "budget excludes too much: {} eligible",
        eligible.len()
    );
    let step = (eligible.len() / SAMPLE_TARGET).max(1);
    let picked: Vec<TconvProblem> =
        eligible.into_iter().step_by(step).take(SAMPLE_TARGET).collect();
    assert!(picked.len() >= 30, "differential sample must cover >= 30 configs");
    picked
}

fn case(p: &TconvProblem, seed: u64) -> (Tensor<i8>, Tensor<i8>, Vec<i32>) {
    let mut rng = Pcg32::new(seed);
    let x = Tensor::<i8>::random(&[p.ih, p.iw, p.ic], &mut rng);
    let w = Tensor::<i8>::random(&[p.oc, p.ks, p.ks, p.ic], &mut rng);
    let bias: Vec<i32> = (0..p.oc).map(|i| (i as i32 % 13) * 7 - 40).collect();
    (x, w, bias)
}

/// Accelerator sim == CPU baseline == direct reference, and cached-plan
/// instantiation == fresh compilation, across the whole sample.
#[test]
fn sampled_sweep_accel_cpu_and_cached_plan_agree() {
    let cfg = AccelConfig::default();
    let cache = PlanCache::new(SAMPLE_TARGET + 1);
    let problems = sample();
    let n = problems.len();

    for (i, p) in problems.iter().enumerate() {
        let (x, w, bias) = case(p, 1000 + i as u64);
        let want = reference::direct_i32(p, &x, &w, Some(&bias));

        let cpu = baseline::tconv_i32(p, &x, &w, Some(&bias), 2);
        assert_eq!(cpu.data(), want.data(), "cpu baseline {p}");

        // Freshly compiled stream.
        let fresh_stream = build_layer_stream(p, &x, &w, &bias, None, &cfg, OutMode::Raw32);
        let fresh = Accelerator::new(cfg.clone())
            .execute(&fresh_stream)
            .unwrap_or_else(|e| panic!("{p}: {e}"));
        assert_eq!(fresh.raw.data(), want.data(), "fresh-plan accelerator {p}");

        // Cold cache entry, then a guaranteed hit.
        let key = PlanKey::new(p, OutMode::Raw32, &cfg, &w, &bias, None);
        let _ = cache
            .get_or_compile(key, || compile_layer(p, &w, &bias, None, &cfg, OutMode::Raw32));
        let plan = cache.get_or_compile(key, || panic!("second lookup must hit: {p}"));
        let cached_stream = plan.instantiate(&x);
        let cached = Accelerator::new(cfg.clone())
            .execute(&cached_stream)
            .unwrap_or_else(|e| panic!("{p} (cached): {e}"));

        // Byte-identical outputs *and* identical cycle accounting: the
        // cached plan emits the same stream, so the model sees no
        // difference at all.
        assert_eq!(cached.raw.data(), fresh.raw.data(), "cached vs fresh {p}");
        assert_eq!(
            cached.report.total_cycles, fresh.report.total_cycles,
            "cached plan changed the cycle model for {p}"
        );
    }

    let s = cache.stats();
    assert_eq!(s.misses, n as u64, "one compile per distinct config");
    assert_eq!(s.hits, n as u64, "one hit per re-lookup");
}

/// Weight-reuse batching over the sweep sample: a same-layer batch of 3
/// requests issues exactly one `LoadWeights` per tile (not 3), its
/// outputs are byte-identical to per-request execution, and the shared
/// timeline is strictly cheaper than the per-request sum.
#[test]
fn sampled_sweep_batched_execution_bit_exact_and_amortized() {
    use mm2im::accel::isa::Instr;
    let cfg = AccelConfig::default();
    // Every other sampled config keeps debug-mode runtime in budget while
    // still spanning the grid axes.
    for (i, p) in sample().iter().enumerate().step_by(2) {
        let (x0, w, bias) = case(p, 2000 + i as u64);
        let mut rng = Pcg32::new(3000 + i as u64);
        let x1 = Tensor::<i8>::random(&[p.ih, p.iw, p.ic], &mut rng);
        let x2 = Tensor::<i8>::random(&[p.ih, p.iw, p.ic], &mut rng);
        let xs = [&x0, &x1, &x2];

        let plan = compile_layer(p, &w, &bias, None, &cfg, OutMode::Raw32);
        let stream = plan.instantiate_batch(&xs);
        let loads = stream.iter().filter(|ins| matches!(ins, Instr::LoadWeights(_))).count();
        assert_eq!(loads, plan.tiles.len(), "one LoadWeights per tile for {p}");

        let batch = Accelerator::new(cfg.clone())
            .run_batch(&stream)
            .unwrap_or_else(|e| panic!("{p} (batched): {e}"));
        assert_eq!(batch.outputs.len(), xs.len());

        let mut per_request_cycles = 0u64;
        for (k, x) in xs.iter().enumerate() {
            let single = Accelerator::new(cfg.clone())
                .execute(&plan.instantiate(x))
                .unwrap_or_else(|e| panic!("{p} (request {k}): {e}"));
            assert_eq!(
                batch.outputs[k].0.data(),
                single.raw.data(),
                "batched vs per-request {p}, request {k}"
            );
            per_request_cycles += single.report.total_cycles;
        }
        assert_eq!(batch.report.weight_loads, plan.tiles.len() as u64);
        assert!(
            batch.report.total_cycles < per_request_cycles,
            "{p}: batch {} vs per-request {per_request_cycles}",
            batch.report.total_cycles
        );
    }
}

/// Kernel-segregated mapper over the sweep sample: for every sampled
/// config the segregated twin must be bit-exact with the overlapped
/// walk, the CPU baseline, and the direct reference — and its plan
/// identity must differ (the mapper is part of the [`PlanKey`], so the
/// cache can never hand one walk's plan to the other). Assert messages
/// carry the case's RNG seed so a CI failure is reproducible verbatim.
#[test]
fn sampled_sweep_segregated_mapper_matches_overlapped_and_cpu() {
    let cfg = AccelConfig::default();
    for (i, p) in sample().iter().enumerate() {
        let seed = 4000 + i as u64;
        let (x, w, bias) = case(p, seed);
        let seg = p.with_mapper(MapperKind::Segregated);
        let want = reference::direct_i32(p, &x, &w, Some(&bias));

        let cpu = baseline::tconv_i32(&seg, &x, &w, Some(&bias), 2);
        assert_eq!(cpu.data(), want.data(), "{seg}: cpu baseline (case seed {seed})");

        let over = Accelerator::new(cfg.clone())
            .execute(&build_layer_stream(p, &x, &w, &bias, None, &cfg, OutMode::Raw32))
            .unwrap_or_else(|e| panic!("{p} overlapped (case seed {seed}): {e}"));
        let got = Accelerator::new(cfg.clone())
            .execute(&build_layer_stream(&seg, &x, &w, &bias, None, &cfg, OutMode::Raw32))
            .unwrap_or_else(|e| panic!("{seg} segregated (case seed {seed}): {e}"));

        assert_eq!(
            got.raw.data(),
            want.data(),
            "{seg}: segregated diverges from reference (case seed {seed})"
        );
        assert_eq!(
            got.raw.data(),
            over.raw.data(),
            "{seg}: segregated vs overlapped (case seed {seed})"
        );

        let k_over = PlanKey::new(p, OutMode::Raw32, &cfg, &w, &bias, None);
        let k_seg = PlanKey::new(&seg, OutMode::Raw32, &cfg, &w, &bias, None);
        assert_ne!(k_over, k_seg, "{seg}: mapper must be part of plan identity");
    }
}

/// Plan-cache identity is engine- and host-parallelism-blind: keys
/// built under the scalar vs fused engine, or under different
/// `host_threads`/`host_parallel_min_macs` knobs, are equal — one
/// compilation serves every execution strategy — while a real device
/// knob (UF) still splits plans. Regression fence for the
/// [`AccelConfig::fingerprint`] exclusion list.
#[test]
fn plan_cache_identity_ignores_engine_and_host_parallelism_knobs() {
    let p = TconvProblem::new(5, 5, 16, 3, 8, 2);
    let (_, w, bias) = case(&p, 9000);
    let base = AccelConfig::default();
    let scalar = AccelConfig { exec_engine: ExecEngine::Scalar, ..base.clone() };
    let wide = AccelConfig { host_threads: 8, host_parallel_min_macs: 0, ..base.clone() };

    let key = PlanKey::new(&p, OutMode::Raw32, &base, &w, &bias, None);
    assert_eq!(key, PlanKey::new(&p, OutMode::Raw32, &scalar, &w, &bias, None));
    assert_eq!(key, PlanKey::new(&p, OutMode::Raw32, &wide, &w, &bias, None));

    // One shared cache entry: compiled under the fused default, hit by
    // lookups from both excluded-knob variants.
    let cache = PlanCache::new(4);
    let _ = cache
        .get_or_compile(key, || compile_layer(&p, &w, &bias, None, &base, OutMode::Raw32));
    for cfg in [&scalar, &wide] {
        let k = PlanKey::new(&p, OutMode::Raw32, cfg, &w, &bias, None);
        let _ = cache.get_or_compile(k, || panic!("excluded knob must hit the shared plan"));
    }
    assert_eq!(cache.stats().hits, 2);
    assert_eq!(cache.stats().misses, 1);

    // A knob that changes the emitted stream still splits identity.
    let narrow = AccelConfig { uf: 8, ..base };
    assert_ne!(
        key,
        PlanKey::new(&p, OutMode::Raw32, &narrow, &w, &bias, None),
        "device knobs must keep splitting plans"
    );
}

/// The sample spans the paper's grid axes (not a corner of the space).
#[test]
fn sample_spans_grid_axes() {
    let problems = sample();
    let distinct = |f: fn(&TconvProblem) -> usize| {
        let mut v: Vec<usize> = problems.iter().map(f).collect();
        v.sort_unstable();
        v.dedup();
        v.len()
    };
    assert!(distinct(|p| p.ks) >= 2, "kernel sizes");
    assert!(distinct(|p| p.ic) >= 3, "input channels");
    assert!(distinct(|p| p.ih) >= 3, "input heights");
    assert!(distinct(|p| p.stride) == 2, "both strides");
    assert!(distinct(|p| p.oc) >= 2, "output channels");
}
