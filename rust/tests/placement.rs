//! Differential + property guarantees for heterogeneous, weight-aware
//! placement.
//!
//! * A heterogeneous fleet (mixed `AccelConfig` shards) must produce
//!   byte-identical outputs to a homogeneous single-shard server across
//!   the 32-config sweep sample — backend choice changes cycles, never
//!   bytes.
//! * Property: under shuffled submission against randomly-configured
//!   fleets, every response arrives exactly once, outputs equal the
//!   per-request reference, and every placement decision picked a shard
//!   whose modeled latency was within the scorer's tolerance of the
//!   minimum.
//! * The `PlanKey` weight digest is computed once per layer per graph
//!   lifetime, no matter how many batches the server runs.

use mm2im::accel::AccelConfig;
use mm2im::bench::workloads::{hetero_fleet, sweep261};
use mm2im::coordinator::{PlacementPolicy, Request, Server, ServerBuilder};
use mm2im::driver::Delegate;
use mm2im::model::executor::Executor;
use mm2im::model::graph::{Graph, Layer};
use mm2im::model::zoo;
use mm2im::tconv::TconvProblem;
use mm2im::tensor::Tensor;
use mm2im::util::prop::check;
use mm2im::util::rng::Pcg32;
use std::collections::HashMap;
use std::sync::Arc;

/// Same deterministic sample as `tests/differential_sweep.rs`: all sweep
/// configs within a debug-mode MAC budget, evenly strided to 32.
const MAC_BUDGET: u64 = 4_000_000;
const SAMPLE_TARGET: usize = 32;

fn sample() -> Vec<TconvProblem> {
    let eligible: Vec<TconvProblem> = sweep261()
        .into_iter()
        .map(|e| e.problem)
        .filter(|p| p.macs() <= MAC_BUDGET)
        .collect();
    let step = (eligible.len() / SAMPLE_TARGET).max(1);
    let picked: Vec<TconvProblem> =
        eligible.into_iter().step_by(step).take(SAMPLE_TARGET).collect();
    assert!(picked.len() >= 30, "placement sample must cover >= 30 configs");
    picked
}

/// The heterogeneous fleet under test: the canonical bench fleet
/// (X=8/UF=16 + X=4/UF=32) plus a wide-array, shallow-unroll variant.
fn hetero_accels() -> Vec<AccelConfig> {
    let mut fleet = hetero_fleet();
    fleet.push(AccelConfig { x_pms: 16, uf: 8, ..AccelConfig::default() });
    fleet
}

/// Serve `seeds_per_graph` requests per graph on the builder's
/// configuration, returning outputs keyed by `(graph, seed)` plus the
/// run's stats.
fn serve_all(
    graphs: &[Arc<Graph>],
    builder: ServerBuilder,
    seeds_per_graph: u64,
) -> (HashMap<(usize, u64), Vec<i8>>, mm2im::coordinator::ServeStats) {
    let mut server = builder.graphs(graphs.to_vec()).start().expect("valid config");
    server.pause();
    // Interleave graphs so grouping and placement both do real work.
    for seed in 0..seeds_per_graph {
        for graph in 0..graphs.len() {
            server.try_submit(Request::seed(seed).graph(graph)).expect("capacity sized");
        }
    }
    server.resume();
    let (responses, stats) = server.finish();
    assert_eq!(responses.len(), graphs.len() * seeds_per_graph as usize);
    let mut out = HashMap::new();
    for r in responses {
        let seed = r.seed().expect("seeded request");
        let prev = out.insert((r.graph, seed), r.output_tensor().data().to_vec());
        assert!(prev.is_none(), "duplicate response for graph {} seed {seed}", r.graph);
    }
    (out, stats)
}

/// Differential acceptance criterion: a heterogeneous fleet serves the
/// whole sweep sample byte-identically to a homogeneous single-shard
/// server, and every recorded placement decision respects the scorer's
/// tolerance.
#[test]
fn hetero_fleet_matches_homogeneous_single_shard_on_sweep_sample() {
    let graphs: Vec<Arc<Graph>> = sample()
        .into_iter()
        .enumerate()
        .map(|(i, p)| Arc::new(zoo::single_tconv(&format!("sweep_{i}"), p, 4000 + i as u64)))
        .collect();
    let tolerance = 0.05;

    let hetero_cfg = Server::builder()
        .workers_per_shard(1)
        .queue_capacity(128)
        .max_batch(2)
        .group_window(256)
        .plan_cache_capacity(4 * graphs.len())
        .shard_fleet(hetero_accels())
        .placement(PlacementPolicy::Modeled { tolerance });
    let homo_cfg = Server::builder()
        .shards(1)
        .workers_per_shard(1)
        .queue_capacity(128)
        .max_batch(2)
        .group_window(256)
        .plan_cache_capacity(2 * graphs.len());

    let (hetero, hetero_stats) = serve_all(&graphs, hetero_cfg, 2);
    let (homo, _) = serve_all(&graphs, homo_cfg, 2);

    assert_eq!(hetero.len(), homo.len());
    for (key, want) in &homo {
        let got = &hetero[key];
        assert_eq!(
            got, want,
            "graph {} seed {}: heterogeneous fleet diverged from single-shard reference",
            key.0, key.1
        );
    }

    // The fleet really was heterogeneous, and the scorer stayed honest.
    assert_eq!(hetero_stats.shard_config_fps.len(), 3);
    assert_ne!(hetero_stats.shard_config_fps[0], hetero_stats.shard_config_fps[1]);
    assert_ne!(hetero_stats.shard_config_fps[0], hetero_stats.shard_config_fps[2]);
    assert_eq!(hetero_stats.placements.len(), hetero_stats.batches as usize);
    for d in &hetero_stats.placements {
        assert_eq!(d.scores_s.len(), 3);
        let min = d.scores_s.iter().copied().fold(f64::INFINITY, f64::min);
        assert!(
            d.scores_s[d.shard] <= min * (1.0 + tolerance) + 1e-12,
            "decision outside tolerance: {d:?}"
        );
    }
}

/// Property: random fleet shapes x shuffled submission. Exactly-once
/// responses, per-request-reference numerics, and tolerance-respecting
/// placement decisions.
#[test]
fn prop_shuffled_submission_random_fleet_exactly_once_within_tolerance() {
    let p0 = TconvProblem::new(5, 5, 16, 3, 8, 2);
    let p1 = TconvProblem::new(4, 4, 8, 3, 6, 1);
    check("placement-shuffled-hetero", 5, |g| {
        let graphs = vec![
            Arc::new(zoo::single_tconv("g0", p0, g.case_seed ^ 0xa)),
            Arc::new(zoo::single_tconv("g1", p1, g.case_seed ^ 0xb)),
        ];
        // Random fleet: 2-3 shards drawn from a config pool.
        let pool = hetero_accels();
        let shards = g.int(2, 3);
        let shard_accels: Vec<AccelConfig> =
            (0..shards).map(|_| pool[g.int(0, pool.len() - 1)].clone()).collect();
        let tolerance = [0.0, 0.02, 0.1][g.int(0, 2)];
        let builder = Server::builder()
            .graphs(graphs.clone())
            .workers_per_shard(g.int(1, 2))
            .queue_capacity(32)
            .max_batch(g.int(1, 3))
            .shard_fleet(shard_accels)
            .placement(PlacementPolicy::Modeled { tolerance });

        // Shuffled multi-graph submission.
        let n = g.int(6, 10) as u64;
        let mut submissions: Vec<(usize, u64)> =
            (0..n).map(|seed| (g.int(0, 1), seed)).collect();
        for i in (1..submissions.len()).rev() {
            let j = g.int(0, i);
            submissions.swap(i, j);
        }

        let mut server = builder.start().expect("valid config");
        server.pause();
        for &(graph, seed) in &submissions {
            server.try_submit(Request::seed(seed).graph(graph)).expect("capacity sized");
        }
        server.resume();
        let (responses, stats) = server.finish();

        // Exactly once: every id 0..n, sorted after drain.
        let ids: Vec<u64> = responses.iter().map(|r| r.id).collect();
        assert_eq!(ids, (0..n).collect::<Vec<u64>>(), "lost/duplicated/unsorted responses");

        // Numerics equal the per-request reference on the default config.
        let reference = Executor::new(Delegate::new(AccelConfig::default(), 1, true));
        for r in &responses {
            let graph = &graphs[r.graph];
            let mut rng = Pcg32::new(r.seed().expect("seeded request"));
            let input = Tensor::<i8>::random(&graph.input_shape, &mut rng);
            let want = reference.run(graph, &input);
            assert_eq!(
                r.output_tensor().data(),
                want.output.data(),
                "graph {} seed {:?} diverged on shard {:?}",
                r.graph,
                r.seed(),
                r.shard
            );
        }

        // Every decision within tolerance of the per-decision minimum.
        assert_eq!(stats.placements.len(), stats.batches as usize);
        for d in &stats.placements {
            let min = d.scores_s.iter().copied().fold(f64::INFINITY, f64::min);
            assert!(
                d.scores_s[d.shard] <= min * (1.0 + tolerance) + 1e-12,
                "tolerance {tolerance} violated: {d:?}"
            );
        }
    });
}

/// ROADMAP regression at the serving level: a graph's weight tensors are
/// digested exactly once for the server's whole lifetime — batches,
/// shards, and heterogeneous configs notwithstanding.
#[test]
fn server_lifetime_hashes_each_weight_tensor_once() {
    let g = Arc::new(zoo::pix2pix(8, 2, 3));
    for layer in &g.layers {
        if let Layer::Tconv { w, .. } = layer {
            assert_eq!(w.fingerprint_computes(), 0, "fresh graph: nothing digested yet");
        }
    }
    let mut server = Server::builder()
        .graph(g.clone())
        .workers_per_shard(1)
        .queue_capacity(16)
        .max_batch(2)
        .shard_fleet(hetero_accels())
        .start()
        .expect("valid config");
    for seed in 0..8 {
        server.submit(Request::seed(seed)).expect("seeded submit");
    }
    let (responses, stats) = server.finish();
    assert_eq!(responses.len(), 8);
    assert!(stats.batches >= 4, "several batches => several PlanKey lookups per layer");
    for layer in &g.layers {
        if let Layer::Tconv { w, .. } = layer {
            assert_eq!(
                w.fingerprint_computes(),
                1,
                "layer weights must be digested exactly once per graph lifetime"
            );
        }
    }
}
