//! Property-based invariants (custom `util::prop` runner, seeds printed
//! on failure and replayable with PROP_SEED=<seed>).
//!
//! These are the coordinator/architecture invariants DESIGN.md §6 calls
//! out: hardware mapper == software maps, simulator == reference
//! numerics under arbitrary shapes/configs, Algorithm-1 streaming
//! feasibility, fixed-point requant == real arithmetic within 1 LSB.

use mm2im::accel::isa::OutMode;
use mm2im::accel::mapper::Mapper;
use mm2im::accel::{Accelerator, AccelConfig};
use mm2im::coordinator::{Request, Server};
use mm2im::cpu::{baseline, gemm};
use mm2im::driver::instructions::{build_layer_stream, compile_layer};
use mm2im::driver::{PlanCache, PlanKey};
use mm2im::model::zoo;
use mm2im::tconv::maps::{for_each_entry, OutputMap, RowSchedule};
use mm2im::tconv::{reference, TconvProblem};
use mm2im::tensor::quant::{self, QuantizedMultiplier};
use mm2im::tensor::Tensor;
use mm2im::util::prop::{check, Gen};
use std::collections::HashMap;
use std::sync::Arc;

fn arb_problem(g: &mut Gen) -> TconvProblem {
    TconvProblem::new(
        g.int(1, 7),
        g.int(1, 7),
        g.int(1, 40),
        g.int(1, 7),
        g.int(1, 20),
        g.int(1, 3),
    )
}

/// Hardware MM2IM Mapper (Algorithm 2, accel::mapper) emits exactly the
/// software output map for every MatMul row.
#[test]
fn prop_hw_mapper_equals_sw_maps() {
    check("hw-mapper==sw-maps", 150, |g| {
        let p = arb_problem(g);
        let m = Mapper::configure(&p);
        for row in 0..p.m() {
            let mut want = Vec::new();
            for_each_entry(&p, row, |c, o| want.push((c, o)));
            assert_eq!(m.matmul_row_entries(row), want, "{p} row {row}");
        }
    });
}

/// Mapper's contributing_rows == RowSchedule (Algorithm 1's i_end_row).
#[test]
fn prop_mapper_schedule_agree() {
    check("mapper-schedule", 200, |g| {
        let p = arb_problem(g);
        let m = Mapper::configure(&p);
        let sched = RowSchedule::build(&p);
        for h in 0..p.oh() {
            assert_eq!(m.contributing_rows(h), sched.contributions[h], "{p} h={h}");
        }
    });
}

/// End-to-end simulator == direct reference for arbitrary problems AND
/// arbitrary architecture scaling (X, UF, row buffer, ablations).
#[test]
fn prop_simulator_bit_exact_any_architecture() {
    check("sim-bit-exact", 60, |g| {
        let p = arb_problem(g);
        let mut cfg = AccelConfig::default();
        cfg.x_pms = g.int(1, 12);
        cfg.uf = *g.pick(&[4usize, 8, 16, 32]);
        cfg.mapper_enabled = g.bool();
        cfg.cmap_skip_enabled = g.bool();
        cfg.overlap_axi_compute = g.bool();
        cfg.row_buffer_rows = g.int(((p.ks + p.stride - 1) / p.stride).max(1), 16);
        let x = Tensor::from_vec(&[p.ih, p.iw, p.ic], g.vec_i8(p.input_elems()));
        let w = Tensor::from_vec(&[p.oc, p.ks, p.ks, p.ic], g.vec_i8(p.weight_elems()));
        let bias: Vec<i32> = (0..p.oc).map(|_| g.int(0, 2000) as i32 - 1000).collect();
        let want = reference::direct_i32(&p, &x, &w, Some(&bias));
        let stream = build_layer_stream(&p, &x, &w, &bias, None, &cfg, OutMode::Raw32);
        let got = Accelerator::new(cfg).execute(&stream).unwrap_or_else(|e| panic!("{p}: {e}"));
        assert_eq!(got.raw.data(), want.data(), "{p}");
    });
}

/// The CPU baseline (any thread count) == reference.
#[test]
fn prop_cpu_baseline_bit_exact() {
    check("cpu-bit-exact", 80, |g| {
        let p = arb_problem(g);
        let threads = g.int(1, 4);
        let x = Tensor::from_vec(&[p.ih, p.iw, p.ic], g.vec_i8(p.input_elems()));
        let w = Tensor::from_vec(&[p.oc, p.ks, p.ks, p.ic], g.vec_i8(p.weight_elems()));
        let want = reference::direct_i32(&p, &x, &w, None);
        let got = baseline::tconv_i32(&p, &x, &w, None, threads);
        assert_eq!(got.data(), want.data(), "{p} threads={threads}");
    });
}

/// GEMM: threading must never change results.
#[test]
fn prop_gemm_thread_invariant() {
    check("gemm-threads", 100, |g| {
        let (m, n, k) = (g.int(1, 24), g.int(1, 24), g.int(1, 48));
        let a = g.vec_i8(m * k);
        let b = g.vec_i8(k * n);
        let mut c1 = vec![0i32; m * n];
        gemm::gemm_i8_i32(m, n, k, &a, &b, &mut c1, 1);
        for threads in [2, 3, 8] {
            let mut ct = vec![0i32; m * n];
            gemm::gemm_i8_i32(m, n, k, &a, &b, &mut ct, threads);
            assert_eq!(c1, ct, "m={m} n={n} k={k} t={threads}");
        }
    });
}

/// Surviving map entries partition the full IOM work: survivors + drops
/// == M * Ks^2, and survivor multiset of outputs covers [0, Oh*Ow) when
/// Ks >= S.
#[test]
fn prop_map_partition_and_coverage() {
    check("map-partition", 200, |g| {
        let p = arb_problem(g);
        let map = OutputMap::build(&p);
        assert_eq!(
            map.surviving_taps() + map.dropped_taps(),
            p.m() * p.ks * p.ks,
            "{p}"
        );
        if p.ks >= p.stride {
            let mut covered = vec![false; p.oh() * p.ow()];
            for e in &map.entries {
                covered[e.out as usize] = true;
            }
            assert!(covered.iter().all(|&c| c), "{p}");
        }
    });
}

/// Algorithm-1 feasibility: with a row buffer of ceil(Ks/S) rows, every
/// Schedule's contributing rows are still resident when needed.
#[test]
fn prop_row_buffer_minimum_capacity_suffices() {
    check("row-buffer-capacity", 120, |g| {
        let p = arb_problem(g);
        let min_cap = ((p.ks + p.stride - 1) / p.stride).max(1);
        let sched = RowSchedule::build(&p);
        // walk Algorithm 1, tracking the sliding window of sent rows
        let mut sent_hi: i64 = -1;
        for h in 0..p.oh() {
            sent_hi = sent_hi.max(sched.i_end_row[h]);
            for &(row, _) in &sched.contributions[h] {
                assert!((row as i64) <= sent_hi, "{p}: row {row} not yet sent at h={h}");
                assert!(
                    (sent_hi - row as i64) < min_cap as i64,
                    "{p}: row {row} evicted (window {min_cap}) at h={h}"
                );
            }
        }
    });
}

/// Fixed-point requant tracks real-valued multiplication within 1 LSB
/// across the full accumulator range.
#[test]
fn prop_requant_within_one_lsb() {
    check("requant-1lsb", 300, |g| {
        let acc = g.int(0, 2_000_000) as i32 - 1_000_000;
        let real = 1e-4 + (g.int(0, 10_000) as f64) * 1e-5; // (1e-4, 0.1]
        let qm = QuantizedMultiplier::from_real(real);
        let got = quant::requantize(acc, qm, 0) as i32;
        let want = ((acc as f64 * real).round() as i32).clamp(-128, 127);
        assert!((got - want).abs() <= 1, "acc={acc} real={real} got={got} want={want}");
    });
}

/// Cycle reports are monotone in workload: adding output channels can
/// never reduce total cycles (same everything else).
#[test]
fn prop_cycles_monotone_in_oc() {
    check("cycles-monotone-oc", 30, |g| {
        let base = arb_problem(g);
        let p1 = TconvProblem::new(base.ih, base.iw, base.ic, base.ks, base.oc, base.stride);
        let p2 = TconvProblem::new(base.ih, base.iw, base.ic, base.ks, base.oc + 8, base.stride);
        let cfg = AccelConfig::default();
        let run = |p: &TconvProblem| {
            let x = Tensor::from_vec(&[p.ih, p.iw, p.ic], vec![1i8; p.input_elems()]);
            let w = Tensor::from_vec(&[p.oc, p.ks, p.ks, p.ic], vec![1i8; p.weight_elems()]);
            let stream = build_layer_stream(p, &x, &w, &vec![0; p.oc], None, &cfg, OutMode::Raw32);
            Accelerator::new(cfg.clone()).execute(&stream).unwrap().report.total_cycles
        };
        assert!(run(&p2) >= run(&p1), "{p1} vs {p2}");
    });
}

/// Plan cache invariants: a key hits right after its insert, distinct
/// problems/configs/params produce distinct keys, and eviction (capacity
/// 1, two alternating layers) never changes numerics.
#[test]
fn prop_plan_cache_hit_distinct_keys_eviction_safe() {
    check("plan-cache", 25, |g| {
        let p = arb_problem(g);
        let mut cfg = AccelConfig::default();
        cfg.x_pms = g.int(1, 10);
        let w = Tensor::from_vec(&[p.oc, p.ks, p.ks, p.ic], g.vec_i8(p.weight_elems()));
        let bias: Vec<i32> = (0..p.oc).map(|_| g.int(0, 200) as i32 - 100).collect();
        let key = PlanKey::new(&p, OutMode::Raw32, &cfg, &w, &bias, None);

        // Hit after insert: the second lookup must not re-compile.
        let cache = PlanCache::new(g.int(1, 4));
        let plan1 = cache
            .get_or_compile(key, || compile_layer(&p, &w, &bias, None, &cfg, OutMode::Raw32));
        let plan2 = cache.get_or_compile(key, || panic!("hit-after-insert violated: {p}"));
        assert!(Arc::ptr_eq(&plan1, &plan2), "{p}");
        let s = cache.stats();
        assert_eq!((s.hits, s.misses), (1, 1), "{p}");

        // Distinct inputs => distinct keys.
        let p2 = TconvProblem::new(p.ih + 1, p.iw, p.ic, p.ks, p.oc, p.stride);
        assert_ne!(key, PlanKey::new(&p2, OutMode::Raw32, &cfg, &w, &bias, None), "{p}");
        let mut cfg2 = cfg.clone();
        cfg2.uf = cfg.uf + 8;
        assert_ne!(key, PlanKey::new(&p, OutMode::Raw32, &cfg2, &w, &bias, None), "{p}");
        let mut w2 = w.clone();
        w2.data_mut()[0] = w.data()[0].wrapping_add(1);
        assert_ne!(key, PlanKey::new(&p, OutMode::Raw32, &cfg, &w2, &bias, None), "{p}");

        // Eviction never changes numerics: capacity-1 cache thrashing
        // between two layers still executes both bit-exactly, twice.
        let pb = arb_problem(g);
        let xb_a = Tensor::from_vec(&[p.ih, p.iw, p.ic], g.vec_i8(p.input_elems()));
        let xb_b = Tensor::from_vec(&[pb.ih, pb.iw, pb.ic], g.vec_i8(pb.input_elems()));
        let wb = Tensor::from_vec(&[pb.oc, pb.ks, pb.ks, pb.ic], g.vec_i8(pb.weight_elems()));
        let biasb: Vec<i32> = (0..pb.oc).map(|_| g.int(0, 200) as i32 - 100).collect();
        let tiny = PlanCache::new(1);
        let cases = [(&p, &xb_a, &w, &bias), (&pb, &xb_b, &wb, &biasb)];
        for round in 0..2 {
            for (prob, x, wt, bs) in cases {
                let want = reference::direct_i32(prob, x, wt, Some(bs));
                let k = PlanKey::new(prob, OutMode::Raw32, &cfg, wt, bs, None);
                let plan = tiny.get_or_compile(k, || {
                    compile_layer(prob, wt, bs, None, &cfg, OutMode::Raw32)
                });
                let got = Accelerator::new(cfg.clone())
                    .execute(&plan.instantiate(x))
                    .unwrap_or_else(|e| panic!("{prob}: {e}"));
                assert_eq!(got.raw.data(), want.data(), "{prob} round {round}");
            }
        }
    });
}

/// Server determinism: outputs depend only on the request seed — never on
/// worker/shard count or submission order.
#[test]
fn prop_server_deterministic_across_topology_and_order() {
    let graph = Arc::new(zoo::pix2pix(8, 2, 0));

    // Golden outputs from a strictly sequential server.
    let n_max = 8u64;
    let mut golden: HashMap<u64, Vec<i8>> = HashMap::new();
    let mut base = Server::builder()
        .graph(graph.clone())
        .shards(1)
        .workers_per_shard(1)
        .start()
        .expect("valid config");
    for seed in 0..n_max {
        base.submit(Request::seed(seed)).expect("seeded submit");
    }
    for r in base.drain() {
        golden.insert(r.seed().expect("seeded request"), r.output_tensor().data().to_vec());
    }

    check("server-determinism", 5, |g| {
        let n = g.int(3, n_max as usize) as u64;
        let mut seeds: Vec<u64> = (0..n).collect();
        for i in (1..seeds.len()).rev() {
            let j = g.int(0, i);
            seeds.swap(i, j);
        }
        let mut server = Server::builder()
            .graph(graph.clone())
            .shards(g.int(1, 3))
            .workers_per_shard(g.int(1, 2))
            .max_batch(g.int(1, 3))
            .queue_capacity(g.int(2, 8))
            .start()
            .expect("valid config");
        server.submit_many(seeds.iter().map(|&s| Request::seed(s))).expect("submit");
        let responses = server.drain();
        assert_eq!(responses.len(), seeds.len());
        for r in &responses {
            let seed = r.seed().expect("seeded request");
            assert_eq!(
                r.output_tensor().data(),
                golden[&seed].as_slice(),
                "seed {seed} diverged under shuffled submission"
            );
        }
        // Ids reflect submission order and come back sorted.
        let ids: Vec<u64> = responses.iter().map(|r| r.id).collect();
        assert_eq!(ids, (0..n).collect::<Vec<u64>>());
    });
}

/// Analytical perf model stays within 12% of the simulator on arbitrary
/// problems (the §V-F property, with margin for the random tail).
#[test]
fn prop_perf_model_accuracy() {
    check("perf-model-12pct", 40, |g| {
        let p = TconvProblem::new(
            g.int(2, 10),
            g.int(2, 10),
            g.int(8, 256),
            g.int(2, 7),
            g.int(4, 64),
            g.int(1, 2),
        );
        let cfg = AccelConfig::default();
        let x = Tensor::from_vec(&[p.ih, p.iw, p.ic], g.vec_i8(p.input_elems()));
        let w = Tensor::from_vec(&[p.oc, p.ks, p.ks, p.ic], g.vec_i8(p.weight_elems()));
        let stream = build_layer_stream(&p, &x, &w, &vec![0; p.oc], None, &cfg, OutMode::Raw32);
        let sim = Accelerator::new(cfg.clone()).execute(&stream).unwrap().report.total_cycles as f64;
        let est = mm2im::perf_model::estimate(&p, &cfg).t_total as f64;
        let err = (est - sim).abs() / sim;
        assert!(err < 0.12, "{p}: sim {sim} est {est} err {:.1}%", err * 100.0);
    });
}
