//! Differential fuzz net for the NT-GEMM kernel matrix
//! (`cpu::gemm::GemmKernel`): every SIMD path compiled into this binary
//! must be **bit-identical** to the scalar oracle — randomized shapes
//! including 0/1/odd/unaligned-tail sizes, saturation extremes at the
//! i8 rails, and the accumulate-into-C contract — plus dispatch checks
//! that the force-scalar override really takes the scalar path. This is
//! the fence around the `unsafe` kernels: any widening, saturation, or
//! tail-handling bug in an intrinsic path shows up as an i32 mismatch
//! against the oracle.

use mm2im::cpu::gemm::{
    compiled_kernels, detect_kernel, force_nt_kernel, gemm_i8_i32_nt, gemm_i8_i32_nt_scalar,
    gemm_i8_i32_nt_with, nt_kernel, resolve_env_choice, GemmKernel,
};
use mm2im::util::prop;

/// Shapes that hit every blocking boundary: empty operands, single
/// rows/cols, the 2-wide j tail, and k tails around the 16-lane SIMD
/// step (15/16/17, 31/32/33) plus deep-k layers.
const EDGE_SIZES: &[usize] = &[0, 1, 2, 3, 5, 7, 8, 15, 16, 17, 31, 32, 33, 63, 64, 65, 100];

fn assert_all_kernels_match(m: usize, n: usize, k: usize, a: &[i8], b: &[i8], ctx: &str) {
    // Oracle accumulates into a non-zero C: the += contract is part of
    // what the SIMD paths must reproduce.
    let mut want = vec![-7i32; m * n];
    gemm_i8_i32_nt_scalar(m, n, k, a, b, &mut want);
    for &kernel in compiled_kernels() {
        if kernel == GemmKernel::Scalar {
            continue;
        }
        let mut got = vec![-7i32; m * n];
        gemm_i8_i32_nt_with(kernel, m, n, k, a, b, &mut got);
        assert_eq!(got, want, "{ctx}: kernel {kernel} diverges from scalar (m={m} n={n} k={k})");
    }
    // The default dispatch entry must agree too, whatever it picked.
    let mut got = vec![-7i32; m * n];
    gemm_i8_i32_nt(m, n, k, a, b, &mut got);
    assert_eq!(got, want, "{ctx}: dispatched kernel diverges (m={m} n={n} k={k})");
}

/// Randomized m/n/k with heavy weight on blocking-tail sizes, random
/// operands: every compiled kernel == scalar oracle, bit for bit.
#[test]
fn fuzz_random_shapes_all_kernels_match_scalar() {
    prop::check("gemm-kernel-differential", 120, |g| {
        let m = if g.bool() { *g.pick(EDGE_SIZES) } else { g.int(0, 24) };
        let n = if g.bool() { *g.pick(EDGE_SIZES) } else { g.int(0, 24) };
        let k = if g.bool() { *g.pick(EDGE_SIZES) } else { g.int(0, 300) };
        let a = g.vec_i8(m * k);
        let b = g.vec_i8(n * k);
        assert_all_kernels_match(m, n, k, &a, &b, "random");
    });
}

/// Saturation extremes: operands pinned to the i8 rails (+127, -128,
/// alternating) are where an i16-saturating formulation (e.g. a
/// maddubs-style trick applied carelessly) would diverge. The widening
/// paths must stay exact.
#[test]
fn saturation_extremes_all_kernels_match_scalar() {
    let patterns: &[fn(usize) -> i8] = &[
        |_| 127,
        |_| -128,
        |i| if i % 2 == 0 { 127 } else { -128 },
        |i| if i % 2 == 0 { -128 } else { 127 },
        |i| [127, -128, 127, 1, -1][i % 5],
    ];
    for k in [1usize, 15, 16, 17, 64, 1024, 4096] {
        for (pi, pa) in patterns.iter().enumerate() {
            for (pj, pb) in patterns.iter().enumerate() {
                let a: Vec<i8> = (0..3 * k).map(*pa).collect();
                let b: Vec<i8> = (0..5 * k).map(*pb).collect();
                assert_all_kernels_match(3, 5, k, &a, &b, &format!("extremes a#{pi} b#{pj}"));
            }
        }
    }
}

/// k around the exactness argument's comfort zone: deep-k at full
/// magnitude must still match (the i32 bound holds to k = 2^17; the
/// deepest layer in the zoo is Ic = 1024).
#[test]
fn deep_k_full_magnitude_matches() {
    let k = 8192;
    let a = vec![-128i8; 2 * k];
    let b = vec![-128i8; 2 * k];
    let mut want = vec![0i32; 4];
    gemm_i8_i32_nt_scalar(2, 2, k, &a, &b, &mut want);
    assert_eq!(want, vec![128 * 128 * k as i32; 4], "oracle sanity");
    assert_all_kernels_match(2, 2, k, &a, &b, "deep-k");
}

/// Every compiled kernel handles the degenerate shapes (m, n, or k of
/// zero) as a no-op / zero-sum without touching out-of-range memory.
#[test]
fn degenerate_shapes_are_noops() {
    for &kernel in compiled_kernels() {
        let mut c: Vec<i32> = vec![];
        gemm_i8_i32_nt_with(kernel, 0, 0, 0, &[], &[], &mut c);
        let mut c = vec![9i32; 6];
        gemm_i8_i32_nt_with(kernel, 2, 3, 0, &[], &[], &mut c);
        assert_eq!(c, vec![9; 6], "{kernel}: k=0 must leave C untouched");
        let b = vec![1i8; 28];
        let mut c: Vec<i32> = vec![];
        gemm_i8_i32_nt_with(kernel, 0, 4, 7, &[], &b, &mut c);
    }
}

/// The force-scalar override really takes the scalar path, and
/// releasing it restores env/detected dispatch. (The env-var side of
/// the knob is exercised by the CI kernel matrix, which runs this whole
/// suite under `MM2IM_GEMM_KERNEL=scalar`.)
#[test]
fn force_scalar_override_takes_scalar_path() {
    let baseline = nt_kernel(); // whatever env/detection picked
    force_nt_kernel(Some(GemmKernel::Scalar));
    assert_eq!(nt_kernel(), GemmKernel::Scalar, "override must take the scalar path");
    // Dispatch under the override still computes correct sums.
    let (m, n, k) = (3, 4, 33);
    let a: Vec<i8> = (0..m * k).map(|i| (i % 251) as i8).collect();
    let b: Vec<i8> = (0..n * k).map(|i| (i % 83) as i8).collect();
    let mut want = vec![0i32; m * n];
    gemm_i8_i32_nt_scalar(m, n, k, &a, &b, &mut want);
    let mut got = vec![0i32; m * n];
    gemm_i8_i32_nt(m, n, k, &a, &b, &mut got);
    assert_eq!(got, want);
    force_nt_kernel(None);
    assert_eq!(nt_kernel(), baseline, "releasing the override restores dispatch");
    // Forcing an uncompiled/unsupported kernel clamps to scalar rather
    // than executing an illegal path.
    let bogus = if cfg!(target_arch = "x86_64") { GemmKernel::Neon } else { GemmKernel::Avx2 };
    force_nt_kernel(Some(bogus));
    assert_eq!(nt_kernel(), GemmKernel::Scalar, "unsupported force clamps to the oracle");
    force_nt_kernel(None);
}

/// A typo'd `MM2IM_GEMM_KERNEL` must abort dispatch resolution loudly
/// — never silently fall back to a kernel that wasn't the one CI asked
/// to exercise. (`resolve_env_choice` is the exact function the cached
/// process-wide dispatch runs at first use.)
#[test]
#[should_panic(expected = "unknown kernel")]
fn bogus_env_kernel_name_panics_at_resolution() {
    let _ = resolve_env_choice(Some("bogus"));
}

/// The accepted `MM2IM_GEMM_KERNEL` vocabulary resolves without
/// panicking: unset/empty/`auto` defer to detection, known names pick
/// their kernel or clamp to the scalar oracle when unsupported.
#[test]
fn env_vocabulary_resolves_cleanly() {
    assert_eq!(resolve_env_choice(None), detect_kernel());
    assert_eq!(resolve_env_choice(Some("")), detect_kernel());
    assert_eq!(resolve_env_choice(Some("auto")), detect_kernel());
    assert_eq!(resolve_env_choice(Some("scalar")), GemmKernel::Scalar);
    for name in ["avx2", "neon", "neondot"] {
        let k = GemmKernel::from_name(name).expect("known name");
        let resolved = resolve_env_choice(Some(name));
        assert_eq!(resolved, if k.supported() { k } else { GemmKernel::Scalar }, "{name}");
    }
}

/// Detection returns a kernel the CPU can actually execute, and the
/// compiled-kernel list it picks from leads with the oracle.
#[test]
fn detection_is_consistent_with_support() {
    let k = detect_kernel();
    assert!(k.supported(), "detected kernel {k} must be runnable");
    assert!(k.compiled(), "detected kernel {k} must be compiled in");
    assert_eq!(compiled_kernels()[0], GemmKernel::Scalar);
    // Name round-trip for the env vocabulary.
    assert_eq!(GemmKernel::from_name(k.name()), Some(k));
}
