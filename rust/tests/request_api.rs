//! Property net for the typed request API: random priority / deadline /
//! cancellation interleavings against random small topologies.
//!
//! * **Exactly-once delivery** — every ticket resolves to exactly one of
//!   `Ok` / `Cancelled` / `DeadlineExpired`, and
//!   `served + cancelled + deadline_expired == submitted`.
//! * **Outcome correctness** — tickets cancelled while queued resolve
//!   `Cancelled`; zero-deadline requests resolve `DeadlineExpired` (the
//!   expiry sweep precedes every batch formation); unconstrained and
//!   generous-deadline requests resolve `Ok`.
//! * **Byte-identical survivors** — outputs of surviving requests equal
//!   the same seeds served by a uniform-priority, no-deadline,
//!   no-cancellation server: service classes steer *scheduling order*,
//!   never numerics.
//!
//! The bounded-inversion guarantee itself (a low-priority request is
//! passed over at most `group_window` times before seeding a batch) is
//! pinned deterministically at the scheduler level in
//! `coordinator::tests::low_priority_request_is_passed_over_at_most_window_times`;
//! here the same machinery runs under random traffic with live workers.

use mm2im::coordinator::{Outcome, Priority, Request, Server, Ticket};
use mm2im::model::zoo;
use mm2im::util::prop::check;
use std::collections::HashMap;
use std::sync::Arc;
use std::time::Duration;

/// What we decided for each submitted request, to check its outcome.
#[derive(Clone, Copy, Debug, PartialEq)]
enum Fate {
    Survive,
    Cancel,
    Expire,
}

#[test]
fn prop_priority_deadline_cancel_interleavings_exactly_once_and_byte_identical() {
    let g0 = Arc::new(zoo::pix2pix(8, 2, 0));
    let g1 = Arc::new(zoo::pix2pix(8, 2, 9));
    let graphs = vec![g0, g1];

    // Golden outputs: every (graph, seed) served by a uniform-priority
    // sequential server with no deadlines and no cancellations.
    let n_max = 12u64;
    let mut golden: HashMap<(usize, u64), Vec<i8>> = HashMap::new();
    let mut base = Server::builder()
        .graphs(graphs.clone())
        .shards(1)
        .workers_per_shard(1)
        .queue_capacity(2 * n_max as usize)
        .start()
        .expect("valid config");
    for seed in 0..n_max {
        for graph in 0..graphs.len() {
            base.submit(Request::seed(seed).graph(graph)).expect("seeded submit");
        }
    }
    for r in base.drain() {
        assert_eq!(r.outcome, Outcome::Ok);
        golden.insert((r.graph, r.seed().unwrap()), r.output_tensor().data().to_vec());
    }

    check("request-api-interleavings", 6, |g| {
        let n = g.int(6, n_max as usize) as u64;
        let shards = g.int(1, 2);
        let max_batch = g.int(1, 3);
        let mut server = Server::builder()
            .graphs(graphs.clone())
            .shards(shards)
            .workers_per_shard(1)
            .max_batch(max_batch)
            .queue_capacity(n as usize + 1)
            .start()
            .expect("valid config");

        // Submit the whole interleaving while paused, so cancellations
        // deterministically win their race (the requests are queued).
        server.pause();
        let mut fates: Vec<Fate> = Vec::new();
        let mut tickets: Vec<Ticket> = Vec::new();
        for seed in 0..n {
            let priority = *g.pick(&[Priority::High, Priority::Normal, Priority::Low]);
            let fate = match g.int(0, 4) {
                0 => Fate::Cancel,
                1 => Fate::Expire,
                _ => Fate::Survive,
            };
            let mut req = Request::seed(seed).graph(g.int(0, 1)).priority(priority);
            req = match fate {
                // A lapsed deadline: must drop at the first sweep.
                Fate::Expire => req.deadline(Duration::ZERO),
                // Survivors sometimes carry a generous deadline — it must
                // not change their outcome.
                Fate::Survive if g.bool() => req.deadline(Duration::from_secs(3600)),
                _ => req,
            };
            let ticket = server.try_submit(req).expect("capacity covers the burst");
            assert_eq!(ticket.id(), seed, "ids are submission order");
            fates.push(fate);
            tickets.push(ticket);
        }
        // Cancel the chosen tickets — every one is still queued.
        for (ticket, fate) in tickets.iter().zip(&fates) {
            if *fate == Fate::Cancel {
                assert!(ticket.cancel(), "queued ticket must cancel");
                assert!(!ticket.cancel(), "cancellation is idempotent");
            }
        }
        server.resume();
        let (responses, stats) = server.finish();

        // Exactly once: every id 0..n, sorted after drain.
        let ids: Vec<u64> = responses.iter().map(|r| r.id).collect();
        assert_eq!(ids, (0..n).collect::<Vec<u64>>(), "lost/duplicated/unsorted responses");
        assert_eq!(
            stats.requests + stats.cancelled + stats.deadline_expired,
            stats.submitted,
            "outcome ledger must balance: {stats:?}"
        );

        for (r, fate) in responses.iter().zip(&fates) {
            let want = match fate {
                Fate::Survive => Outcome::Ok,
                Fate::Cancel => Outcome::Cancelled,
                Fate::Expire => Outcome::DeadlineExpired,
            };
            assert_eq!(r.outcome, want, "id {} fate {fate:?}", r.id);
            match r.outcome {
                Outcome::Ok => {
                    assert!(r.shard.is_some());
                    // Byte-identical to the uniform-priority golden run:
                    // classes reorder service, never change numerics.
                    let key = (r.graph, r.seed().expect("seeded request"));
                    assert_eq!(
                        r.output_tensor().data(),
                        golden[&key].as_slice(),
                        "graph {} seed {} diverged from the uniform-priority run",
                        key.0,
                        key.1
                    );
                }
                _ => {
                    assert!(r.output.is_none());
                    assert_eq!(r.shard, None);
                    assert_eq!(r.wall_seconds, 0.0);
                    assert_eq!(r.modeled_seconds, 0.0);
                }
            }
        }
    });
}

/// Unpaused variant: cancellations race live workers. Outcomes are no
/// longer fully predetermined — a cancel that returns `false` lost the
/// race and must resolve `Ok` — but exactly-once and the stats ledger
/// hold regardless of who wins.
#[test]
fn prop_racing_cancellations_keep_exactly_once() {
    let graph = Arc::new(zoo::pix2pix(8, 2, 0));
    check("request-api-racing-cancel", 4, |g| {
        let n = g.int(6, 12) as u64;
        let mut server = Server::builder()
            .graph(graph.clone())
            .shards(g.int(1, 2))
            .workers_per_shard(g.int(1, 2))
            .max_batch(2)
            .queue_capacity(4)
            .start()
            .expect("valid config");
        let mut cancels: Vec<(Ticket, bool)> = Vec::new();
        for seed in 0..n {
            let ticket = server.submit(Request::seed(seed)).expect("seeded submit");
            if g.bool() {
                let won = ticket.cancel();
                cancels.push((ticket, won));
            }
        }
        let (responses, stats) = server.finish();
        assert_eq!(
            responses.iter().map(|r| r.id).collect::<Vec<u64>>(),
            (0..n).collect::<Vec<u64>>(),
            "every ticket resolves exactly once"
        );
        assert_eq!(stats.requests + stats.cancelled, stats.submitted);
        for (ticket, won) in cancels {
            let want = if won { Outcome::Cancelled } else { Outcome::Ok };
            assert_eq!(responses[ticket.id() as usize].outcome, want, "id {}", ticket.id());
        }
    });
}
