//! Cross-module integration tests: driver -> simulator -> crossbar vs the
//! software references, delegate equivalence, calibration bands vs the
//! paper's Table II, and the trend claims of §V-B.

use mm2im::accel::isa::OutMode;
use mm2im::accel::{Accelerator, AccelConfig};
use mm2im::bench::harness::run_problem;
use mm2im::bench::workloads::sweep261;
use mm2im::cpu::baseline;
use mm2im::driver::instructions::build_layer_stream;
use mm2im::driver::Delegate;
use mm2im::model::zoo;
use mm2im::tconv::metrics::DropStats;
use mm2im::tconv::{reference, TconvProblem};
use mm2im::tensor::quant::{PerChannel, QuantParams};
use mm2im::tensor::Tensor;
use mm2im::util::rng::Pcg32;
use mm2im::util::stats;

fn rand_case(p: &TconvProblem, seed: u64) -> (Tensor<i8>, Tensor<i8>, Vec<i32>) {
    let mut rng = Pcg32::new(seed);
    let x = Tensor::<i8>::random(&[p.ih, p.iw, p.ic], &mut rng);
    let w = Tensor::<i8>::random(&[p.oc, p.ks, p.ks, p.ic], &mut rng);
    let bias: Vec<i32> = (0..p.oc).map(|i| (i as i32 % 11) * 9 - 40).collect();
    (x, w, bias)
}

/// Every 10th sweep problem: full pipeline bit-exactness (simulator vs
/// direct reference vs CPU baseline).
#[test]
fn sweep_subset_simulator_cpu_reference_agree() {
    let cfg = AccelConfig::default();
    for (i, e) in sweep261().iter().enumerate().step_by(10) {
        let p = e.problem;
        let (x, w, bias) = rand_case(&p, i as u64);
        let want = reference::direct_i32(&p, &x, &w, Some(&bias));
        let cpu = baseline::tconv_i32(&p, &x, &w, Some(&bias), 2);
        assert_eq!(cpu.data(), want.data(), "cpu {p}");
        let stream = build_layer_stream(&p, &x, &w, &bias, None, &cfg, OutMode::Raw32);
        let acc = Accelerator::new(cfg.clone()).execute(&stream).unwrap();
        assert_eq!(acc.raw.data(), want.data(), "accelerator {p}");
    }
}

/// Quantized path: accelerator PPU output == CPU fixed-point requant,
/// byte for byte (the paper's §V-E correctness methodology).
#[test]
fn quantized_ppu_matches_cpu_requant() {
    let cfg = AccelConfig::default();
    for (p, seed) in [
        (TconvProblem::square(7, 32, 5, 16, 2), 1u64),
        (TconvProblem::square(9, 64, 3, 32, 1), 2),
        (TconvProblem::square(5, 128, 7, 8, 2), 3),
    ] {
        let (x, w, bias) = rand_case(&p, seed);
        let out_q = QuantParams { scale: 0.07, zero_point: 5 };
        let requant = PerChannel::new(0.05, &vec![0.02; p.oc], out_q);
        let acc = Delegate::new(cfg.clone(), 2, true);
        let cpu = Delegate::new(cfg.clone(), 2, false);
        let (a, _) = acc.run_tconv_quant(&p, &x, &w, &bias, 0, &requant).unwrap();
        let (c, _) = cpu.run_tconv_quant(&p, &x, &w, &bias, 0, &requant).unwrap();
        assert_eq!(a.data(), c.data(), "{p}");
    }
}

/// Calibration: simulated accelerator latencies for Table II land within
/// the documented bands of the paper's measurements (EXPERIMENTS.md
/// §Calibration; StyleTransfer_1/2 are the known deviations).
#[test]
fn table2_latency_calibration_bands() {
    let cfg = AccelConfig::default();
    for row in zoo::table2_layers() {
        let r = run_problem(&row.problem, &cfg, 1);
        let model_ms = r.acc_seconds * 1e3;
        let ratio = model_ms / row.paper_acc_ms;
        let band = match row.name {
            "StyleTransfer_1" | "StyleTransfer_2" => (0.1, 1.2), // known deviation
            _ => (0.5, 1.5),
        };
        assert!(
            ratio > band.0 && ratio < band.1,
            "{}: modeled {model_ms:.2}ms vs paper {:.2}ms (ratio {ratio:.2})",
            row.name,
            row.paper_acc_ms
        );
    }
}

/// §V-B takeaways as assertions over the full sweep results.
#[test]
fn fig6_trend_claims_hold() {
    let cfg = AccelConfig::default();
    // (ii) larger Ic -> greater speedup (fixed everything else)
    let s_by_ic: Vec<f64> = [32usize, 64, 128, 256]
        .iter()
        .map(|&ic| run_problem(&TconvProblem::square(9, ic, 5, 32, 2), &cfg, 1).speedup_2t())
        .collect();
    for w in s_by_ic.windows(2) {
        assert!(w[1] > w[0] * 0.98, "Ic trend: {s_by_ic:?}");
    }
    // (iii) larger Ih -> greater (or equal) speedup
    let s_by_ih: Vec<f64> = [7usize, 9, 11]
        .iter()
        .map(|&ih| run_problem(&TconvProblem::square(ih, 128, 5, 32, 2), &cfg, 1).speedup_2t())
        .collect();
    assert!(s_by_ih[2] > s_by_ih[0] * 0.95, "Ih trend: {s_by_ih:?}");
    // (v) higher stride -> lower speedup
    let s1 = run_problem(&TconvProblem::square(9, 128, 5, 32, 1), &cfg, 1).speedup_2t();
    let s2 = run_problem(&TconvProblem::square(9, 128, 5, 32, 2), &cfg, 1).speedup_2t();
    assert!(s2 < s1, "stride trend: s1 {s1} s2 {s2}");
    // paper: stride-2 speedup averages ~54% of stride-1
    let ratio = s2 / s1;
    assert!(ratio > 0.3 && ratio < 0.95, "stride-2/stride-1 ratio {ratio}");
}

/// Fig. 7 claims: Ks raises drop rate, stride and Ih lower it.
#[test]
fn fig7_drop_rate_trends() {
    for &s in &[1usize, 2] {
        for &ih in &[7usize, 9, 11] {
            let rates: Vec<f64> = [3usize, 5, 7]
                .iter()
                .map(|&ks| DropStats::compute(&TconvProblem::square(ih, 64, ks, 32, s)).d_r)
                .collect();
            assert!(rates[0] <= rates[1] && rates[1] <= rates[2], "ks trend {rates:?}");
        }
    }
}

/// The sweep's average speedup against the dual-thread CPU lands in a
/// band around the paper's 1.9x claim. Our simulator is faster than the
/// paper's HLS artifact on large-feature-map layers (EXPERIMENTS.md), so
/// the band is generous on the high side.
#[test]
fn sweep_average_speedup_band() {
    let cfg = AccelConfig::default();
    // Every 5th problem is statistically representative and keeps CI fast.
    let speedups: Vec<f64> = sweep261()
        .iter()
        .step_by(5)
        .map(|e| run_problem(&e.problem, &cfg, 3).speedup_2t())
        .collect();
    let mean = stats::mean(&speedups);
    let geo = stats::geomean(&speedups);
    assert!(mean > 1.2 && mean < 6.0, "mean speedup {mean}");
    assert!(geo > 1.0, "geomean {geo}");
    // accelerator should win on the majority of problems
    let wins = speedups.iter().filter(|&&s| s > 1.0).count();
    assert!(wins * 10 >= speedups.len() * 7, "wins {wins}/{}", speedups.len());
}

/// Driver streams must be replayable: executing the same stream twice
/// gives identical outputs and identical cycle reports.
#[test]
fn instruction_stream_replay_deterministic() {
    let p = TconvProblem::square(7, 64, 5, 16, 2);
    let (x, w, bias) = rand_case(&p, 77);
    let cfg = AccelConfig::default();
    let stream = build_layer_stream(&p, &x, &w, &bias, None, &cfg, OutMode::Raw32);
    let a = Accelerator::new(cfg.clone()).execute(&stream).unwrap();
    let b = Accelerator::new(cfg).execute(&stream).unwrap();
    assert_eq!(a.raw.data(), b.raw.data());
    assert_eq!(a.report.total_cycles, b.report.total_cycles);
    assert_eq!(a.report.traffic, b.report.traffic);
}

/// Scaling X and UF (the paper's "these parameters could be scaled"):
/// numerics invariant, cycles monotone.
#[test]
fn architecture_scaling_preserves_numerics() {
    let p = TconvProblem::square(6, 48, 5, 24, 2);
    let (x, w, bias) = rand_case(&p, 5);
    let want = reference::direct_i32(&p, &x, &w, Some(&bias));
    let mut cycles = Vec::new();
    for (x_pms, uf) in [(1, 4), (2, 8), (4, 16), (8, 16), (16, 32)] {
        let mut cfg = AccelConfig::default();
        cfg.x_pms = x_pms;
        cfg.uf = uf;
        let stream = build_layer_stream(&p, &x, &w, &bias, None, &cfg, OutMode::Raw32);
        let r = Accelerator::new(cfg).execute(&stream).unwrap();
        assert_eq!(r.raw.data(), want.data(), "X={x_pms} UF={uf}");
        cycles.push(r.report.total_cycles);
    }
    for w in cycles.windows(2) {
        assert!(w[1] <= w[0], "more hardware must not be slower: {cycles:?}");
    }
}

// ---------------------------------------------------------------------------
// Failure injection: driver/accelerator contract violations must be caught,
// not silently mis-executed.
// ---------------------------------------------------------------------------

mod failure_injection {
    use super::*;
    use mm2im::accel::isa::{FilterPayload, Instr, TileConfig, WeightSet};

    fn tiny() -> (TconvProblem, Tensor<i8>, Tensor<i8>, Vec<i32>) {
        let p = TconvProblem::square(3, 4, 3, 2, 1);
        let (x, w, b) = rand_case(&p, 1);
        (p, x, w, b)
    }

    fn payloads(p: &TconvProblem, w: &Tensor<i8>, n: usize) -> WeightSet {
        let filters = (0..n)
            .map(|oc| {
                let mut weights = Vec::new();
                for kh in 0..p.ks {
                    for kw in 0..p.ks {
                        for c in 0..p.ic {
                            weights.push(w.at4(oc, kh, kw, c));
                        }
                    }
                }
                FilterPayload {
                    weights: weights.into(),
                    bias: 0,
                    qmult_m: 1 << 30,
                    qmult_shift: 1,
                    zp_out: 0,
                }
            })
            .collect();
        WeightSet::new(filters, p.ks, p.ic)
    }

    fn exec(stream: Vec<Instr>) -> Result<(), String> {
        Accelerator::new(AccelConfig::default()).execute(&stream).map(|_| ())
    }

    #[test]
    fn weights_before_configure_rejected() {
        let (p, _x, w, _b) = tiny();
        let err = exec(vec![Instr::LoadWeights(payloads(&p, &w, 2))]).unwrap_err();
        assert!(err.contains("before Configure"), "{err}");
    }

    #[test]
    fn wrong_filter_count_rejected() {
        let (p, _x, w, _b) = tiny();
        let tc = TileConfig {
            problem: p,
            oc_base: 0,
            oc_count: 2,
            out_mode: OutMode::Raw32,
        };
        let err = exec(vec![
            Instr::Configure(tc),
            Instr::LoadWeights(payloads(&p, &w, 1)),
        ])
        .unwrap_err();
        assert!(err.contains("filters"), "{err}");
    }

    #[test]
    fn wrong_input_row_width_rejected() {
        let (p, _x, w, _b) = tiny();
        let tc = TileConfig { problem: p, oc_base: 0, oc_count: 2, out_mode: OutMode::Raw32 };
        let err = exec(vec![
            Instr::Configure(tc),
            Instr::LoadWeights(payloads(&p, &w, 2)),
            Instr::LoadInput { first_row: 0, rows: vec![vec![0i8; 5].into()] },
        ])
        .unwrap_err();
        assert!(err.contains("bytes"), "{err}");
    }

    #[test]
    fn schedule_out_of_range_rejected() {
        let (p, x, w, _b) = tiny();
        let tc = TileConfig { problem: p, oc_base: 0, oc_count: 2, out_mode: OutMode::Raw32 };
        let rows: Vec<mm2im::accel::RowSlice> = (0..p.ih)
            .map(|r| x.data()[r * p.iw * p.ic..(r + 1) * p.iw * p.ic].to_vec().into())
            .collect();
        let err = exec(vec![
            Instr::Configure(tc),
            Instr::LoadWeights(payloads(&p, &w, 2)),
            Instr::LoadInput { first_row: 0, rows },
            Instr::Schedule { out_row: p.oh() },
        ])
        .unwrap_err();
        assert!(err.contains("out of range"), "{err}");
    }

    #[test]
    fn store_without_schedule_rejected() {
        let (p, _x, w, _b) = tiny();
        let tc = TileConfig { problem: p, oc_base: 0, oc_count: 2, out_mode: OutMode::Raw32 };
        let err = exec(vec![
            Instr::Configure(tc),
            Instr::LoadWeights(payloads(&p, &w, 2)),
            Instr::StoreOutput { out_row: 0 },
        ])
        .unwrap_err();
        assert!(err.contains("no completed row"), "{err}");
    }

    #[test]
    fn double_schedule_without_store_rejected() {
        let (p, x, w, _b) = tiny();
        let tc = TileConfig { problem: p, oc_base: 0, oc_count: 2, out_mode: OutMode::Raw32 };
        let rows: Vec<mm2im::accel::RowSlice> = (0..p.ih)
            .map(|r| x.data()[r * p.iw * p.ic..(r + 1) * p.iw * p.ic].to_vec().into())
            .collect();
        let err = exec(vec![
            Instr::Configure(tc),
            Instr::LoadWeights(payloads(&p, &w, 2)),
            Instr::LoadInput { first_row: 0, rows },
            Instr::Schedule { out_row: 0 },
            Instr::Schedule { out_row: 1 },
        ])
        .unwrap_err();
        assert!(err.contains("overwritten"), "{err}");
    }

    #[test]
    fn problem_change_mid_stream_rejected() {
        let (p, _x, _w, _b) = tiny();
        let other = TconvProblem::square(4, 4, 3, 2, 1);
        let err = exec(vec![
            Instr::Configure(TileConfig { problem: p, oc_base: 0, oc_count: 2, out_mode: OutMode::Raw32 }),
            Instr::Configure(TileConfig { problem: other, oc_base: 0, oc_count: 2, out_mode: OutMode::Raw32 }),
        ])
        .unwrap_err();
        assert!(err.contains("changed mid-stream"), "{err}");
    }

    /// Partial layers (missing StoreOutput for some rows) must be flagged
    /// at the end of the stream.
    #[test]
    fn truncated_stream_rejected() {
        let (p, x, w, bias) = tiny();
        let cfg = AccelConfig::default();
        let mut stream = build_layer_stream(&p, &x, &w, &bias, None, &cfg, OutMode::Raw32);
        stream.truncate(stream.len() - 2); // drop last Schedule+Store
        let err = exec(stream).unwrap_err();
        assert!(err.contains("incomplete"), "{err}");
    }
}
