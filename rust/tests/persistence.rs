//! Warm-restart acceptance net for `driver::persist` + the coordinator's
//! `plan_store` wiring.
//!
//! * **Zero-compile warm restart** — a server restarted against the
//!   snapshot its predecessor flushed serves its first request with zero
//!   plan compiles: `plans_preloaded` equals the graph's TCONV layer
//!   count, `cache_misses == 0`, and a single-request run records
//!   exactly `layer count` plan-cache hits.
//! * **Byte-identical outputs** — every warm-served seed matches the
//!   cold run byte for byte (a reloaded plan is the *same* plan).
//! * **Corruption falls back to cold start** — a truncated file, a
//!   flipped payload byte, a wrong format version, and a
//!   foreign-`AccelConfig` snapshot each load as a clean cold start
//!   (zero preloads, full recompile) with outputs still byte-identical
//!   to a reference run; nothing panics.
//! * **Stale fingerprints are structurally dead** — a snapshot whose
//!   `params_fp` no longer matches the live weights *decodes* fine
//!   (its checksums are self-consistent) but preloads only entries no
//!   live lookup can hit: the server recompiles every layer and serves
//!   byte-identical outputs. Wrong cycles are unreachable, not merely
//!   unlikely.

use mm2im::accel::AccelConfig;
use mm2im::coordinator::{Outcome, Request, Response, ServeStats, Server};
use mm2im::driver::persist::{self, FORMAT_VERSION};
use mm2im::model::{zoo, Graph, Layer};
use std::path::{Path, PathBuf};
use std::sync::Arc;

fn store_path(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("mm2im_persist_{tag}_{}.bin", std::process::id()))
}

fn tconv_layers(g: &Graph) -> u64 {
    g.layers.iter().filter(|l| matches!(l, Layer::Tconv { .. })).count() as u64
}

/// Single-shard server (deterministic batching: paused submits of
/// `n` seeds with `max_batch` 2 form exactly `n/2` batches) optionally
/// wired to a plan store, serving seeds `0..n`.
fn run(
    g: &Arc<Graph>,
    cfg: AccelConfig,
    store: Option<&Path>,
    n: u64,
) -> (Vec<Response>, ServeStats) {
    let mut builder = Server::builder()
        .graph(g.clone())
        .shards(1)
        .workers_per_shard(1)
        .queue_capacity(16)
        .max_batch(2)
        .accel(cfg);
    if let Some(path) = store {
        builder = builder.plan_store(path);
    }
    let mut server = builder.start().expect("valid config");
    server.pause();
    for seed in 0..n {
        server.submit(Request::seed(seed)).expect("seeded submit");
    }
    server.resume();
    let (responses, stats) = server.finish();
    assert_eq!(responses.len(), n as usize);
    for r in &responses {
        assert_eq!(r.outcome, Outcome::Ok);
    }
    (responses, stats)
}

fn assert_byte_identical(got: &[Response], want: &[Response]) {
    assert_eq!(got.len(), want.len());
    for w in want {
        let g = got.iter().find(|r| r.id == w.id).expect("same ids served");
        assert_eq!(
            g.output_tensor().data(),
            w.output_tensor().data(),
            "outputs diverged for seed {}",
            w.id
        );
    }
}

/// The acceptance path: cold run flushes on finish, warm run preloads and
/// never compiles, a single-request warm run records exactly
/// `layer count` plan-cache hits, outputs stay byte-identical throughout.
#[test]
fn warm_restart_serves_first_request_with_zero_plan_compiles() {
    let g = Arc::new(zoo::pix2pix(8, 2, 0));
    let layers = tconv_layers(&g);
    let store = store_path("warm");
    let _ = std::fs::remove_file(&store);

    let (cold_responses, cold) = run(&g, AccelConfig::default(), Some(&store), 4);
    assert_eq!(cold.plans_preloaded, 0, "no snapshot yet: cold start");
    assert_eq!(cold.cache_misses, layers, "cold run compiles each layer once");
    assert!(store.exists(), "finish flushes the snapshot");

    // Restart: every plan preloads, nothing compiles, outputs identical.
    let (warm_responses, warm) = run(&g, AccelConfig::default(), Some(&store), 4);
    assert_eq!(warm.plans_preloaded, layers, "whole zoo preloaded from snapshot");
    assert_eq!(warm.cache_misses, 0, "warm restart must not compile a single plan");
    assert_eq!(warm.cache_hits, warm.batches * layers, "every (batch, layer) lookup hits");
    assert_byte_identical(&warm_responses, &cold_responses);

    // The very first request on a fresh restart: plan-cache hits equal
    // the layer count exactly, with zero compiles.
    let (first, stats) = run(&g, AccelConfig::default(), Some(&store), 1);
    assert_eq!(stats.plans_preloaded, layers);
    assert_eq!(stats.cache_misses, 0);
    assert_eq!(stats.cache_hits, layers, "first request resolves every layer from the snapshot");
    assert_byte_identical(&first, &cold_responses[..1]);

    let _ = std::fs::remove_file(&store);
}

/// Each corruption path must load as a clean cold start — never a panic,
/// never a silently wrong plan — and the run's outputs must match the
/// no-snapshot reference byte for byte.
#[test]
fn corrupted_snapshots_fall_back_to_clean_cold_start() {
    let g = Arc::new(zoo::pix2pix(8, 2, 1));
    let layers = tconv_layers(&g);
    let store = store_path("corrupt");
    let _ = std::fs::remove_file(&store);

    // Reference (also produces the pristine snapshot we corrupt below).
    let (reference, _) = run(&g, AccelConfig::default(), Some(&store), 4);
    let pristine = std::fs::read(&store).expect("snapshot flushed");

    let corruptions: Vec<(&str, Vec<u8>)> = vec![
        ("truncated file", pristine[..pristine.len() / 2].to_vec()),
        ("flipped payload byte", {
            let mut b = pristine.clone();
            let last = b.len() - 3;
            b[last] ^= 0x40;
            b
        }),
        ("wrong format version", {
            let mut b = pristine.clone();
            b[8..12].copy_from_slice(&(FORMAT_VERSION + 1).to_le_bytes());
            b
        }),
    ];
    for (what, bytes) in corruptions {
        // decode() itself must reject (typed error, no panic)...
        assert!(persist::decode(&bytes).is_err(), "{what}: decode must reject");
        std::fs::write(&store, &bytes).unwrap();
        // ...and a server pointed at the damaged file cold-starts cleanly.
        let (responses, stats) = run(&g, AccelConfig::default(), Some(&store), 4);
        assert_eq!(stats.plans_preloaded, 0, "{what}: rejected snapshot preloads nothing");
        assert_eq!(stats.cache_misses, layers, "{what}: full recompile");
        assert_byte_identical(&responses, &reference);
        let _ = std::fs::remove_file(&store);
    }

    // Mismatched AccelConfig: the snapshot is *valid* but was saved by a
    // different fleet; the loader filters every entry out by cfg_fp.
    std::fs::write(&store, &pristine).unwrap();
    let narrow = AccelConfig { x_pms: 4, uf: 32, ..AccelConfig::default() };
    let (responses, stats) = run(&g, narrow, Some(&store), 4);
    assert_eq!(stats.plans_preloaded, 0, "foreign-config entries are filtered at load");
    assert_eq!(stats.cache_misses, layers, "foreign-config snapshot means full recompile");
    // Configs change cycles, never numerics.
    assert_byte_identical(&responses, &reference);

    let _ = std::fs::remove_file(&store);
}

/// A stale-weights snapshot (params fingerprints no longer match the
/// live graph) is self-consistent on disk, so it decodes and preloads —
/// but every entry is structurally dead: live `PlanKey`s fold the actual
/// weight-tensor fingerprints, so the stale keys are never looked up,
/// each layer recompiles, and outputs stay byte-identical.
#[test]
fn stale_params_fingerprints_preload_only_dead_entries() {
    let g = Arc::new(zoo::pix2pix(8, 2, 2));
    let layers = tconv_layers(&g);
    let store = store_path("stale");
    let _ = std::fs::remove_file(&store);

    let (reference, _) = run(&g, AccelConfig::default(), Some(&store), 4);

    // Re-key every entry as if it had been compiled from different
    // weights, and re-encode (checksums recomputed: the file is honest
    // about its stale contents, not corrupt).
    let snap = persist::load(&store).expect("pristine snapshot loads");
    let stale: Vec<_> = snap
        .entries
        .into_iter()
        .map(|(mut k, plan)| {
            k.params_fp ^= 1;
            (k, plan)
        })
        .collect();
    std::fs::write(&store, persist::encode(&stale, &snap.header.cfg_fps)).unwrap();

    let (responses, stats) = run(&g, AccelConfig::default(), Some(&store), 4);
    assert_eq!(stats.plans_preloaded, layers, "stale entries pass validation and preload");
    assert_eq!(stats.cache_misses, layers, "stale keys are never hit: every layer recompiles");
    assert_byte_identical(&responses, &reference);

    let _ = std::fs::remove_file(&store);
}
