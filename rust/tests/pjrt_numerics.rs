//! AOT artifact numerics: the HLO text lowered from the Pallas MM2IM
//! kernel (L1) through the JAX graph (L2), executed by the rust PJRT
//! runtime (L3), must match rust-native references (DESIGN.md §6 chain).
//!
//! PJRT execution is pinned to the process main thread (see
//! `runtime::pjrt` module docs for the xla_extension 0.5.1 NaN gotcha),
//! so these tests drive the `repro validate` subcommand as a subprocess
//! and assert on its output. Requires `make artifacts`; skipped with a
//! note when artifacts/ is absent.

use std::process::Command;

fn artifacts_present() -> bool {
    let dir = mm2im::runtime::manifest::default_dir();
    let ok = dir.join("manifest.json").exists();
    if !ok {
        eprintln!("skipping: no artifacts at {dir:?} (run `make artifacts`)");
    }
    ok
}

/// The PJRT backend may be a stub in images without the `xla` crate
/// (see `runtime::pjrt` docs); its absence is a skip, not a failure.
fn pjrt_available() -> bool {
    match mm2im::runtime::PjrtRuntime::cpu() {
        Ok(_) => true,
        Err(e) => {
            eprintln!("skipping: {e}");
            false
        }
    }
}

fn run_validate(extra: &[&str]) -> (bool, String) {
    let out = Command::new(env!("CARGO_BIN_EXE_repro"))
        .arg("validate")
        .args(extra)
        .output()
        .expect("spawn repro validate");
    let text = format!(
        "{}{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    (out.status.success(), text)
}

#[test]
fn validate_subcommand_checks_all_artifacts() {
    if !artifacts_present() || !pjrt_available() {
        return;
    }
    let (ok, text) = run_validate(&[]);
    assert!(ok, "validate failed:\n{text}");
    assert!(text.contains("all artifacts match rust-native numerics"), "{text}");
    assert!(!text.contains("MISMATCH"), "{text}");
    // every tconv artifact in the manifest must have been checked
    let manifest =
        mm2im::runtime::Manifest::load(&mm2im::runtime::manifest::default_dir()).unwrap();
    for meta in manifest.tconv_artifacts() {
        let mm2im::runtime::ArtifactKind::Tconv { name, .. } = &meta.kind else { unreachable!() };
        assert!(text.contains(name.as_str()), "artifact {name} not validated:\n{text}");
    }
    assert!(text.contains("dcgan_gen"), "dcgan artifact not validated:\n{text}");
}

#[test]
fn validate_is_seed_robust() {
    if !artifacts_present() || !pjrt_available() {
        return;
    }
    for seed in ["7", "1234567"] {
        let (ok, text) = run_validate(&["--seed", seed]);
        assert!(ok, "validate --seed {seed} failed:\n{text}");
        assert!(!text.contains("MISMATCH"), "seed {seed}:\n{text}");
    }
}

#[test]
fn manifest_contract_matches_rust_expectations() {
    if !artifacts_present() {
        return;
    }
    let m = mm2im::runtime::Manifest::load(&mm2im::runtime::manifest::default_dir()).unwrap();
    assert!(m.tconv_artifacts().count() >= 3);
    let d = m.dcgan().expect("dcgan artifact");
    let want = mm2im::model::float_ref::param_shapes();
    assert_eq!(d.arg_shapes.len(), 1 + want.len());
    assert_eq!(d.arg_shapes[0], vec![mm2im::model::float_ref::LATENT]);
    for (got, want) in d.arg_shapes[1..].iter().zip(&want) {
        assert_eq!(got, want);
    }
    for meta in m.tconv_artifacts() {
        let mm2im::runtime::ArtifactKind::Tconv { problem: p, .. } = &meta.kind else {
            unreachable!()
        };
        assert_eq!(meta.arg_shapes[0], vec![p.ih, p.iw, p.ic]);
        assert_eq!(meta.arg_shapes[1], vec![p.oc, p.ks, p.ks, p.ic]);
        assert_eq!(meta.arg_shapes[2], vec![p.oc]);
        assert!(meta.returns_tuple);
        assert!(m.path_of(meta).exists());
        let head = std::fs::read_to_string(m.path_of(meta)).unwrap();
        assert!(head.starts_with("HloModule"), "{} is not HLO text", meta.file);
    }
}
