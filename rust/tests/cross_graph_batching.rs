//! Cross-graph PlanKey-chain batching: serving-level guarantees for
//! [`mm2im::coordinator::BatchGrouping::PlanChain`].
//!
//! * Chain-mate graphs (identical layer shapes, different weights) ride
//!   one batch — outputs stay byte-identical to the per-request
//!   reference, and the shared `Configure`/weight prologue amortizes
//!   loads below the per-request equivalent.
//! * A deterministic strict win: under alternating two-variant traffic,
//!   the residency-aware segment reorder lets chain grouping perform
//!   *strictly fewer* `LoadWeights` transfers than graph-identity
//!   grouping on the same traffic, at byte-identical outputs.
//! * Exactly-once delivery: shuffled submission over a mixed
//!   multi-variant fleet (chain-mates + an unrelated graph, two shard
//!   configs) resolves every ticket `Ok` exactly once, byte-identical
//!   to the reference.

use mm2im::accel::AccelConfig;
use mm2im::bench::workloads::hetero_fleet;
use mm2im::coordinator::{BatchGrouping, Outcome, Request, Response, ServeStats, Server};
use mm2im::driver::Delegate;
use mm2im::model::executor::Executor;
use mm2im::model::zoo;
use mm2im::model::Graph;
use mm2im::tconv::TconvProblem;
use mm2im::tensor::Tensor;
use mm2im::util::rng::Pcg32;
use std::collections::HashMap;
use std::sync::Arc;

/// Every served response must match a fresh per-request reference run
/// of its own graph (weights differ per variant, so using the right
/// graph is itself under test).
fn assert_reference_outputs(responses: &[Response], graphs: &[Arc<Graph>]) {
    let reference = Executor::new(Delegate::new(AccelConfig::default(), 1, true));
    for r in responses {
        let graph = &graphs[r.graph];
        let mut rng = Pcg32::new(r.seed().expect("seeded request"));
        let input = Tensor::<i8>::random(&graph.input_shape, &mut rng);
        let want = reference.run(graph, &input);
        assert_eq!(
            r.output_tensor().data(),
            want.output.data(),
            "id {} graph {} ({})",
            r.id,
            r.graph,
            graph.name
        );
    }
}

/// Chain-mates (same pix2pix geometry, different weight seeds) form one
/// mixed batch: byte-identical outputs, a counted cross-graph batch,
/// and amortized weight loads.
#[test]
fn chain_mates_share_a_batch_with_reference_outputs() {
    let graphs = vec![Arc::new(zoo::pix2pix(8, 2, 0)), Arc::new(zoo::pix2pix(8, 2, 7))];
    let mut server = Server::builder()
        .graphs(graphs.iter().cloned())
        .shards(1)
        .workers_per_shard(1)
        .queue_capacity(8)
        .max_batch(4)
        .batch_grouping(BatchGrouping::PlanChain)
        .start()
        .expect("valid config");

    // 3 + 1 requests queued while paused: one batch of four, mixing both
    // variants.
    server.pause();
    for (seed, &graph) in [0usize, 1, 0, 0].iter().enumerate() {
        server.try_submit(Request::seed(seed as u64).graph(graph)).expect("capacity sized");
    }
    server.resume();
    let (responses, stats) = server.finish();
    assert_eq!(responses.len(), 4);
    assert_reference_outputs(&responses, &graphs);

    assert_eq!(stats.batches, 1, "all four requests ride one batch: {stats:?}");
    assert_eq!(stats.cross_graph_batches, 1, "the batch mixed both variants");
    assert!(
        stats.weight_loads < stats.weight_loads_equiv,
        "batched prologues must amortize: {} vs {}",
        stats.weight_loads,
        stats.weight_loads_equiv
    );
}

/// The strict win the residency-aware segment reorder buys. Two
/// single-tile chain-mate graphs under alternating traffic A,B,A,B at
/// `max_batch` 2 and `group_window` 2 on one shard/worker:
///
/// * PlanChain forms two mixed batches. The first pays both loads
///   (2); the second finds B resident from batch 1, rotates B's
///   segment to the front, and its load is elided → 3 performed loads.
/// * GraphIdentity forms four singletons with alternating filter sets —
///   the resident skip never fires → 4 performed loads.
///
/// Both policies must stay byte-identical to each other and to the
/// per-request reference; only the load count may differ.
#[test]
fn plan_chain_beats_graph_identity_on_weight_loads() {
    // Oc = 8 = X: exactly one tile, so "resident" is the whole filter
    // set of the last-loaded variant.
    let p = TconvProblem::new(6, 6, 8, 3, 8, 2);
    let graphs = vec![
        Arc::new(zoo::single_tconv("variant_a", p, 7)),
        Arc::new(zoo::single_tconv("variant_b", p, 21)),
    ];

    let run = |grouping: BatchGrouping| -> (Vec<Response>, ServeStats) {
        let mut server = Server::builder()
            .graphs(graphs.iter().cloned())
            .shards(1)
            .workers_per_shard(1)
            .queue_capacity(8)
            .max_batch(2)
            .group_window(2)
            .batch_grouping(grouping)
            .start()
            .expect("valid config");
        server.pause();
        for (seed, &graph) in [0usize, 1, 0, 1].iter().enumerate() {
            server.try_submit(Request::seed(seed as u64).graph(graph)).expect("capacity sized");
        }
        server.resume();
        let (mut responses, stats) = server.finish();
        responses.sort_by_key(|r| r.id);
        assert_eq!(responses.len(), 4);
        (responses, stats)
    };

    let (chain_responses, chain) = run(BatchGrouping::PlanChain);
    let (ident_responses, ident) = run(BatchGrouping::GraphIdentity);

    // Grouping policy never changes bytes.
    assert_reference_outputs(&chain_responses, &graphs);
    for (a, b) in chain_responses.iter().zip(&ident_responses) {
        assert_eq!(a.output_tensor().data(), b.output_tensor().data(), "id {}", a.id);
    }

    // Batch shapes are fully determined by the scenario.
    assert_eq!(chain.batches, 2, "two mixed pairs: {chain:?}");
    assert_eq!(chain.cross_graph_batches, 2);
    assert_eq!(ident.batches, 4, "four singletons: {ident:?}");
    assert_eq!(ident.cross_graph_batches, 0);

    // The load ledger: 3 performed (one elided via the residency-aware
    // reorder) vs 4 performed with no elision, out of 4 per-request
    // equivalents each.
    assert_eq!(chain.weight_loads_equiv, 4);
    assert_eq!(ident.weight_loads_equiv, 4);
    assert_eq!(chain.weight_loads, 3, "batch 2 leads with the resident variant: {chain:?}");
    assert!(chain.weight_loads_skipped >= 1, "the elision must be visible: {chain:?}");
    assert!(
        chain.cross_batch_resident_hits >= 1,
        "the reorder turns residency into a cross-batch hit: {chain:?}"
    );
    assert_eq!(ident.weight_loads, 4, "alternating singletons never hit: {ident:?}");
    assert_eq!(ident.weight_loads_skipped, 0);
    assert!(
        chain.weight_loads < ident.weight_loads,
        "PlanChain must strictly beat GraphIdentity: {} vs {}",
        chain.weight_loads,
        ident.weight_loads
    );
}

/// Shuffled submission over a mixed multi-variant fleet: two pix2pix
/// chain-mates plus an unrelated DCGAN on the canonical heterogeneous
/// two-shard fleet. Every ticket resolves [`Outcome::Ok`] exactly once,
/// and every output matches the per-request reference.
#[test]
fn shuffled_submission_resolves_exactly_once_over_mixed_fleet() {
    let graphs = vec![
        Arc::new(zoo::pix2pix(8, 2, 3)),
        Arc::new(zoo::pix2pix(8, 2, 11)),
        Arc::new(zoo::dcgan_tf(5)),
    ];
    let mut server = Server::builder()
        .graphs(graphs.iter().cloned())
        .workers_per_shard(1)
        .queue_capacity(32)
        .max_batch(3)
        .shard_fleet(hetero_fleet())
        .batch_grouping(BatchGrouping::PlanChain)
        .start()
        .expect("valid config");

    // Deterministically-shuffled traffic over all three graphs, queued
    // up front so grouping sees the whole pattern.
    server.pause();
    let pattern = [0usize, 2, 1, 0, 1, 2, 0, 1, 0, 2, 1, 0];
    let mut tickets = Vec::new();
    for (seed, &graph) in pattern.iter().enumerate() {
        let t = server.try_submit(Request::seed(seed as u64).graph(graph)).expect("capacity");
        tickets.push(t.id());
    }
    server.resume();
    let (responses, stats) = server.finish();

    // Exactly-once: every submitted id resolves Ok exactly once, and
    // nothing else comes back.
    assert_eq!(responses.len(), pattern.len());
    let mut by_id: HashMap<u64, usize> = HashMap::new();
    for r in &responses {
        assert_eq!(r.outcome, Outcome::Ok, "id {}", r.id);
        *by_id.entry(r.id).or_insert(0) += 1;
    }
    for id in &tickets {
        assert_eq!(by_id.get(id), Some(&1), "ticket {id} must resolve exactly once");
    }
    assert_eq!(by_id.len(), tickets.len());

    // The DCGAN variant can never join a pix2pix chain; the chain-mates
    // may mix. Whatever grouped, bytes must match the reference.
    assert_reference_outputs(&responses, &graphs);
    assert_eq!(stats.requests, pattern.len() as u64);
    assert!(stats.mean_batch_size > 1.0, "prefilled traffic must batch: {stats:?}");
}
