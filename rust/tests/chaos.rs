//! Chaos suite: seeded fault injection against the serving stack.
//!
//! Every scenario drives a real [`Server`] with a deterministic
//! [`FaultPlan`] and pins the supervision contract from the coordinator
//! module docs:
//!
//! * **Exactly-once ledger** — under every fault class,
//!   `served + cancelled + deadline_expired + failed == submitted`, and
//!   each submitted id resolves exactly once.
//! * **Retry correctness** — a request that survives (possibly after
//!   retries on other shards) produces bytes identical to a fault-free
//!   run of the same seed: failed batches commit nothing, so a retry
//!   can never double-apply or corrupt.
//! * **Quarantine** — a shard whose executions keep failing stops
//!   receiving placements until a recovery probe succeeds; without a
//!   revive budget it stays quarantined to close.
//! * **Worker death** — a panicking worker thread is surfaced as
//!   [`ServeError::WorkerFailed`] from `finish`, never as a panic in
//!   the caller, and completed responses still drain.
//! * **Fault injection off** — with `no_fault_injection()` the whole
//!   layer is invisible: zero counters, all shards healthy. This leg is
//!   what keeps the suite meaningful under CI's `MM2IM_FAULT_SPEC`
//!   matrix (the builder override beats the environment).
//!
//! All randomness flows from the fault-spec seed and the request seeds,
//! so every failure here replays from the printed spec alone.

use mm2im::accel::{AccelConfig, FaultPlan, FaultSpec};
use mm2im::coordinator::{
    Outcome, PlacementPolicy, Request, ServeError, ServeStats, Server, ShardHealth,
};
use mm2im::driver::Delegate;
use mm2im::model::executor::Executor;
use mm2im::model::zoo;
use mm2im::tensor::Tensor;
use mm2im::util::rng::Pcg32;
use std::sync::Arc;

/// The exactly-once ledger: every submitted request resolved once.
fn assert_ledger(stats: &ServeStats, responses_len: usize) {
    assert_eq!(
        stats.requests + stats.cancelled + stats.deadline_expired + stats.requests_failed,
        stats.submitted,
        "ledger must balance: {stats:?}"
    );
    assert_eq!(responses_len as u64, stats.submitted, "one response per submission");
}

/// Fault-free reference bytes for a seeded pix2pix(8, 2, 0) request.
fn reference_bytes(graph: &mm2im::model::Graph, seed: u64) -> Vec<i8> {
    let exec = Executor::new(Delegate::new(AccelConfig::default(), 1, true));
    let mut rng = Pcg32::new(seed);
    let input = Tensor::<i8>::random(&graph.input_shape, &mut rng);
    exec.run(graph, &input).output.data().to_vec()
}

/// Build a 2-shard server, queue `n` seeded requests while paused, then
/// release them and finish.
fn run_plan(
    graph: &Arc<mm2im::model::Graph>,
    plan: FaultPlan,
    n: u64,
    retry_budget: u32,
    quarantine_after: u32,
    placement: PlacementPolicy,
) -> (Vec<mm2im::coordinator::Response>, ServeStats) {
    let mut server = Server::builder()
        .graph(graph.clone())
        .shards(2)
        .workers_per_shard(1)
        .queue_capacity(32)
        .max_batch(2)
        .placement(placement)
        .fault_plan(plan)
        .retry_budget(retry_budget)
        .quarantine_after(quarantine_after)
        .start()
        .expect("valid config");
    server.pause();
    for seed in 0..n {
        server.try_submit(Request::seed(seed)).expect("capacity sized");
    }
    server.resume();
    server.finish()
}

/// Acceptance (a): the ledger balances under every fault class —
/// transient faults, corrupt-transfer detections, latency stalls, and a
/// mix — and every request that *did* serve matches the fault-free
/// bytes for its seed.
#[test]
fn ledger_balances_under_every_fault_class() {
    let graph = Arc::new(zoo::pix2pix(8, 2, 0));
    let plans = [
        ("transient", FaultSpec::new(11).transient(0.25)),
        ("corrupt", FaultSpec::new(12).corrupt(0.25)),
        ("stall", FaultSpec::new(13).stall(0.5, 1)),
        ("mixed", FaultSpec::new(14).transient(0.1).corrupt(0.1).stall(0.2, 1)),
    ];
    for (name, spec) in plans {
        let (responses, stats) = run_plan(
            &graph,
            FaultPlan::new(spec),
            10,
            2,
            2,
            PlacementPolicy::RoundRobin,
        );
        assert_ledger(&stats, responses.len());

        // Exactly-once: each submitted id resolves exactly once.
        let mut ids: Vec<u64> = responses.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..10).collect::<Vec<u64>>(), "plan {name}");

        // Retries never perturb numerics: survivors are byte-identical
        // to the fault-free reference for the same seed.
        for r in responses.iter().filter(|r| r.outcome == Outcome::Ok) {
            let want = reference_bytes(&graph, r.seed().expect("seeded"));
            assert_eq!(r.output_tensor().data(), &want[..], "plan {name} id {}", r.id);
        }
        // Failed requests carry no output.
        for r in &responses {
            if let Outcome::Failed(_) = r.outcome {
                assert!(r.output.is_none(), "plan {name} id {}", r.id);
            }
        }
        // A pure-stall plan delays but never fails.
        if name == "stall" {
            assert_eq!(stats.exec_failures, 0, "stalls are latency, not failures");
            assert_eq!(stats.requests_failed, 0);
            assert_eq!(stats.requests, 10);
        }
    }
}

/// Acceptance (b): killing one shard of a two-shard fleet at its first
/// stream completes *every* request on the survivor, byte-identical to
/// the fault-free run of the same seeds.
#[test]
fn single_shard_death_completes_on_survivor_with_identical_bytes() {
    let graph = Arc::new(zoo::pix2pix(8, 2, 0));
    let plan = FaultPlan::new(FaultSpec::new(21).kill(1, 0));
    let (responses, stats) =
        run_plan(&graph, plan, 8, 5, 1, PlacementPolicy::RoundRobin);

    assert_ledger(&stats, responses.len());
    assert_eq!(stats.requests, 8, "all requests must be served: {stats:?}");
    assert_eq!(stats.requests_failed, 0);
    assert!(stats.exec_failures >= 1, "shard 1 must have failed at least once");
    assert!(stats.retries >= 1, "failed batches must have been requeued");
    assert_eq!(stats.shards_quarantined, 1);
    assert_eq!(stats.shard_health, vec![ShardHealth::Healthy, ShardHealth::Quarantined]);
    assert!(stats.worker_failures.is_empty(), "shard death is contained, not a thread death");

    for r in &responses {
        assert_eq!(r.outcome, Outcome::Ok, "id {}", r.id);
        assert_eq!(r.shard, Some(0), "only the survivor serves: id {}", r.id);
        let want = reference_bytes(&graph, r.seed().expect("seeded"));
        assert_eq!(r.output_tensor().data(), &want[..], "id {}", r.id);
    }
}

/// Acceptance (c), no-revive leg: a dead shard is quarantined after its
/// first failure and receives no further placements; recovery probes
/// run but never succeed, so it stays quarantined to close.
#[test]
fn dead_shard_stays_quarantined_without_revive() {
    let graph = Arc::new(zoo::pix2pix(8, 2, 0));
    let plan = FaultPlan::new(FaultSpec::new(31).kill(0, 0));
    let (responses, stats) =
        run_plan(&graph, plan, 8, 5, 1, PlacementPolicy::RoundRobin);

    assert_ledger(&stats, responses.len());
    assert_eq!(stats.requests, 8);
    assert_eq!(stats.shard_health, vec![ShardHealth::Quarantined, ShardHealth::Healthy]);
    assert_eq!(stats.shard_requests[0], 0, "a dead-from-birth shard serves nothing");
    assert!(stats.probes >= 1, "quarantined shards must be probed");
    assert_eq!(stats.probe_recoveries, 0, "no revive budget, no recovery");

    // Placement exclusion: every batch routed to shard 0 failed there
    // (it was dead from stream 0), so placements to shard 0 are bounded
    // by its failures — after quarantine, none are issued at all.
    let to_dead = stats.placements.iter().filter(|d| d.shard == 0).count() as u64;
    assert!(
        to_dead <= stats.exec_failures,
        "placements to the dead shard ({to_dead}) must all predate quarantine \
         (exec failures: {})",
        stats.exec_failures
    );
    for r in &responses {
        assert_eq!(r.shard, Some(1), "id {}", r.id);
    }
}

/// Acceptance (c), revive leg: with a revive budget the first recovery
/// probe succeeds, the shard returns to Healthy, and placements resume
/// — the run ends with both shards serving and zero failed requests.
#[test]
fn probe_recovery_returns_shard_to_service() {
    let graph = Arc::new(zoo::pix2pix(8, 2, 0));
    let plan = FaultPlan::new(FaultSpec::new(41).kill(0, 0).revive_after(0));
    let (responses, stats) =
        run_plan(&graph, plan, 16, 5, 1, PlacementPolicy::RoundRobin);

    assert_ledger(&stats, responses.len());
    assert_eq!(stats.requests, 16);
    assert_eq!(stats.requests_failed, 0);
    assert!(stats.probe_recoveries >= 1, "the revive probe must have fired: {stats:?}");
    assert_eq!(
        stats.shard_health,
        vec![ShardHealth::Healthy, ShardHealth::Healthy],
        "a recovered shard ends Healthy"
    );
    assert!(
        stats.shard_requests[0] > 0,
        "placements must return to the recovered shard: {:?}",
        stats.shard_requests
    );
    for r in &responses {
        let want = reference_bytes(&graph, r.seed().expect("seeded"));
        assert_eq!(r.output_tensor().data(), &want[..], "id {}", r.id);
    }
}

/// Acceptance (d): with fault injection disabled the supervision layer
/// is invisible — zero fault counters, all shards Healthy, no worker
/// failures, and every request serves with reference bytes. The
/// explicit `no_fault_injection()` override beats `MM2IM_FAULT_SPEC`,
/// so this holds even under CI's chaos environment matrix.
#[test]
fn fault_injection_disabled_is_invisible() {
    let graph = Arc::new(zoo::pix2pix(8, 2, 0));
    let mut server = Server::builder()
        .graph(graph.clone())
        .shards(2)
        .workers_per_shard(1)
        .queue_capacity(16)
        .max_batch(2)
        .no_fault_injection()
        .start()
        .expect("valid config");
    for seed in 0..6u64 {
        server.submit(Request::seed(seed)).expect("seeded requests validate");
    }
    let (responses, stats) = server.finish();

    assert_ledger(&stats, responses.len());
    assert_eq!(stats.requests, 6);
    assert_eq!(stats.requests_failed, 0);
    assert_eq!(stats.exec_failures, 0);
    assert_eq!(stats.retries, 0);
    assert_eq!(stats.probes, 0);
    assert_eq!(stats.probe_recoveries, 0);
    assert_eq!(stats.shards_quarantined, 0);
    assert_eq!(stats.shard_health, vec![ShardHealth::Healthy; 2]);
    assert!(stats.worker_failures.is_empty());
    for r in &responses {
        let want = reference_bytes(&graph, r.seed().expect("seeded"));
        assert_eq!(r.output_tensor().data(), &want[..], "id {}", r.id);
    }
}

/// Worker-death regression (satellite): an injected worker-thread abort
/// is captured by `finish` as [`ServeError::WorkerFailed`] — the caller
/// never sees the panic — while responses completed *before* the death
/// still drain, and requests stranded on the dead worker resolve as
/// `Failed(WorkerLost)` so the ledger stays balanced.
#[test]
fn worker_death_surfaces_failure_and_drains_completed_responses() {
    let graph = Arc::new(zoo::pix2pix(8, 2, 0));
    let mut server = Server::builder()
        .graph(graph.clone())
        .shards(1)
        .workers_per_shard(1)
        .queue_capacity(8)
        .max_batch(2)
        // The only worker dies at its second batch take: batch one
        // completes, the rest of the queue is stranded.
        .fault_plan(FaultPlan::new(FaultSpec::new(51).abort(0, 1)))
        .start()
        .expect("valid config");
    server.pause();
    for seed in 0..4u64 {
        server.try_submit(Request::seed(seed)).expect("capacity sized");
    }
    server.resume();
    let (responses, stats) = server.finish();

    assert_eq!(stats.worker_failures.len(), 1, "exactly one worker died: {stats:?}");
    match &stats.worker_failures[0] {
        ServeError::WorkerFailed { worker, message } => {
            assert_eq!(*worker, 0);
            assert!(message.contains("aborted"), "captured panic message: {message}");
        }
        other => panic!("expected WorkerFailed, got {other:?}"),
    }

    assert_ledger(&stats, responses.len());
    assert_eq!(stats.requests, 2, "the first batch completed before the abort");
    assert_eq!(stats.requests_failed, 2, "stranded requests resolve as failed");
    let served: Vec<&mm2im::coordinator::Response> =
        responses.iter().filter(|r| r.outcome == Outcome::Ok).collect();
    assert_eq!(served.len(), 2);
    for r in &served {
        let want = reference_bytes(&graph, r.seed().expect("seeded"));
        assert_eq!(r.output_tensor().data(), &want[..], "id {}", r.id);
    }
    for r in responses.iter().filter(|r| r.outcome != Outcome::Ok) {
        assert_eq!(
            r.outcome,
            Outcome::Failed(mm2im::coordinator::FailReason::WorkerLost),
            "id {}",
            r.id
        );
        assert!(r.output.is_none());
    }
}
